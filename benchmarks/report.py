"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
dryrun_results/{summary,roofline}.json."""

from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_ms(s):
    return f"{s*1e3:.2f}" if s is not None else "-"


def main(out_path=None):
    summary = json.loads((ROOT / "dryrun_results" / "summary.json").read_text())
    roofline = json.loads((ROOT / "dryrun_results" / "roofline.json").read_text())

    lines = []
    lines.append("### Dry-run grid (compile + memory per device)\n")
    lines.append(
        "| arch | shape | mesh | compile s | args/dev | temp/dev | "
        "flops/dev (cost_analysis, scan-body-once) | AG/AR/RS/A2A/CP |"
    )
    lines.append("|---|---|---|---|---|---|---|---|")
    for cid, rec in sorted(summary.items()):
        if rec.get("skipped"):
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | - | SKIP | - | - | - | "
                f"{rec['reason']} |"
            )
            continue
        if not rec.get("ok"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | ? | FAIL | - | - | - | {rec.get('error','')[:60]} |")
            continue
        b = rec["bytes_per_device"]
        c = rec["collectives"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rec['compile_s']} | {fmt_bytes(b['arguments'])} | "
            f"{fmt_bytes(b['temp'])} | {rec['cost_analysis']['flops']:.3g} | "
            f"{c['all-gather']}/{c['all-reduce']}/{c['reduce-scatter']}/"
            f"{c['all-to-all']}/{c['collective-permute']} |"
        )

    lines.append("\n### Roofline (per chip, v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    lines.append(
        "| cell | compute ms | memory ms | collective ms | dominant | "
        "bound ms | roofline frac | MODEL_FLOPS/HLO |"
    )
    lines.append("|---|---|---|---|---|---|---|---|")
    for cid, t in sorted(roofline.items()):
        lines.append(
            f"| {cid} | {fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} | "
            f"{fmt_ms(t['collective_s'])} | {t['dominant']} | "
            f"{fmt_ms(t['step_lower_bound_s'])} | "
            f"{t['roofline_fraction']:.3f} | {t['useful_fraction']:.2f} |"
        )
    text = "\n".join(lines) + "\n"
    if out_path:
        Path(out_path).write_text(text)
    else:
        print(text)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
