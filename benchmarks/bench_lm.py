"""LM-side microbenchmarks: wall time of the reduced-config train/decode
steps on CPU (sanity + regression tracking for the model stack), plus the
kernel-vs-ref walk step throughput."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def lm_steps() -> list[str]:
    from repro.configs import reduced_config
    from repro.models import model_init
    from repro.optim import OptConfig, adamw_init
    from repro.train import make_train_step

    rows = []
    B, S = 2, 32
    for arch in ("llama3.2-1b", "mamba2-2.7b", "mixtral-8x22b",
                 "recurrentgemma-2b", "deepseek-v2-236b"):
        cfg = reduced_config(arch)
        rng = np.random.default_rng(0)
        params = model_init(jax.random.PRNGKey(0), cfg)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
        }
        if cfg.frontend == "vision":
            batch["prefix"] = jnp.zeros((B, cfg.num_prefix, cfg.d_model))
        step = jax.jit(make_train_step(cfg, OptConfig()))
        opt = adamw_init(params)
        dt = _time(lambda p, o, b: step(p, o, b)[2]["loss"], params, opt, batch)
        tok_s = B * S / dt
        rows.append(f"lm_train_{arch},{dt*1e6:.1f},tokens_per_s={tok_s:.0f}")
    return rows


def walk_kernel_throughput() -> list[str]:
    from repro.core import erdos_renyi, partition_into_n_blocks
    from repro.core.graph import BlockView
    from repro.engines.base import ResidentPair
    from repro.kernels import node2vec_step

    g = erdos_renyi(2000, 16000, seed=0)
    bg = partition_into_n_blocks(g, 4)
    rp = ResidentPair(bg, has_alias=False)
    rp.set_slot(0, BlockView.from_resident(bg.materialize_block(0)))
    rp.set_slot(1, BlockView.from_resident(bg.materialize_block(2)))
    pair, v_iters = rp.device_args()
    rng = np.random.default_rng(0)
    n = 4096
    cur = jnp.asarray(rng.integers(bg.block_starts[0], bg.block_starts[1], n).astype(np.int32))
    prev = jnp.asarray(rng.integers(bg.block_starts[2], bg.block_starts[3], n).astype(np.int32))
    wid = jnp.arange(n, dtype=jnp.int32)
    hop = jnp.ones(n, jnp.int32)
    active = jnp.ones(n, bool)
    key = jax.random.PRNGKey(0)
    rows = []
    for use_kernel, name in ((True, "pallas_interpret"), (False, "jnp_ref")):
        fn = lambda: node2vec_step(*pair, wid, prev, cur, hop, active, key,
                                   v_iters=v_iters, use_kernel=use_kernel,
                                   interpret=True)[0]
        dt = _time(lambda: fn())
        rows.append(
            f"walk_step_{name},{dt*1e6:.1f},steps_per_s={n/dt:.0f}"
        )
    return rows
