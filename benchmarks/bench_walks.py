"""Paper-table benchmark harness (scaled to the container).

One function per paper table/figure:

  * fig1_profile        — Fig. 1(a): cost decomposition of 1st vs 2nd order
                          walks on SOGW (vertex I/O dominance).
  * table3_engines      — Table 3: PB vs Bi-Block wall/exec/block-I/O.
  * table4_loading      — Table 4: pure full load vs learning-based load
                          (seq + locality partitions).
  * table6_distributions— Table 6: SOGW/SGSC/GraSorw across synthetic graph
                          families (skew / density / community).
  * table7_first_order  — Table 7: first-order DeepWalk applicability.
  * table8_scheduling   — App. A Table 8: current-block strategies.
  * fig8_end_to_end     — Fig. 8: end-to-end RWNV + PRNV, three systems.

Every entry prints ``name,us_per_call,derived`` CSV rows (us_per_call =
simulated wall time per sampled step in microseconds; derived = the
headline ratio the paper reports for that table).

The storage backends are axes: ``--pool {memory,disk}`` (or
``BENCH_POOL=disk``) runs every engine against the chosen
:mod:`repro.io` WalkPool backend, and ``--graph-backend {ram,disk}`` (or
``BENCH_GRAPH=disk``) serves graph blocks from the packed on-disk
container (:mod:`repro.io.blockfile`) instead of the host-RAM CSR —
recording *real* bytes moved through a file descriptor.  The
``backend_matrix`` entry runs the full pool x graph matrix on a tiny
graph and asserts the deterministic ``IOStats`` are identical across all
four combinations (the CI bench-smoke job uploads its ``--json`` report).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import zlib
from pathlib import Path
from typing import Callable, Dict

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (
    BiBlockEngine,
    PlainBucketEngine,
    SOGWEngine,
    barabasi_albert,
    circulant_graph,
    deepwalk_task,
    erdos_renyi,
    greedy_locality_partition,
    partition_into_n_blocks,
    prnv_task,
    rwnv_task,
    stochastic_block_model,
)

# container-scale knobs (the paper's graphs are ~1000x larger; ratios are
# the reproduction target, and they are scale-stable per §7.6/§7.7)
SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
N_V = int(3000 * SCALE)
N_E = int(24000 * SCALE)
N_BLOCKS = 6
WALKS_PV = 2
LENGTH = 16

#: walk-pool axis — every engine run goes through this backend.  The flush
#: threshold applies to BOTH backends so memory-vs-disk rows differ only in
#: where the spilled bytes go, never in what is charged.
POOL_KW: Dict[str, object] = {
    "pool": os.environ.get("BENCH_POOL", "memory"),
    "pool_flush_walks": int(os.environ.get("BENCH_FLUSH", "4096")),
}


def set_pool_backend(pool: str, flush_walks: int | None = None) -> None:
    POOL_KW.clear()
    POOL_KW["pool"] = pool
    # 0 is meaningful (spill every push) — only None means "default"
    POOL_KW["pool_flush_walks"] = 4096 if flush_walks is None else flush_walks


#: graph-block axis — ``ram`` cuts blocks from the host CSR, ``disk`` writes
#: the packed container once and serves every block via real pread()s.
GRAPH_KW: Dict[str, object] = {
    "backend": os.environ.get("BENCH_GRAPH", "ram"),
    "directory": None,
}
_GRAPH_CACHE: Dict[tuple, object] = {}
#: one shared scratch dir for all containers; the TemporaryDirectory
#: finalizer removes it (and every graph.grb inside) at interpreter exit
_GRAPH_TMPDIR: tempfile.TemporaryDirectory | None = None


def set_graph_backend(backend: str, directory: str | None = None) -> None:
    GRAPH_KW["backend"] = backend
    GRAPH_KW["directory"] = directory
    for dg in _GRAPH_CACHE.values():
        dg.close()
    _GRAPH_CACHE.clear()


def _graph_dir() -> str:
    global _GRAPH_TMPDIR
    if GRAPH_KW["directory"]:
        return str(GRAPH_KW["directory"])
    if _GRAPH_TMPDIR is None:
        _GRAPH_TMPDIR = tempfile.TemporaryDirectory(prefix="bench_graph_")
    return _GRAPH_TMPDIR.name


def _as_backend(bg):
    """Route an in-RAM BlockedGraph through the selected graph backend."""
    if GRAPH_KW["backend"] == "ram":
        return bg
    from repro.io import BLOCK_FILE_NAME, write_and_open

    # content-keyed cache: entries building the same graph/partition twice
    # (every entry rebuilds _default_graph) reuse one serialised container
    g = bg.graph
    key = (
        zlib.crc32(np.ascontiguousarray(bg.block_starts).tobytes()),
        zlib.crc32(np.ascontiguousarray(g.indptr).tobytes()),
        zlib.crc32(np.ascontiguousarray(g.indices).tobytes()),
        g.num_vertices,
        zlib.crc32(np.ascontiguousarray(g.weights).tobytes())
        if g.weights is not None
        else 0,
    )
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = write_and_open(
            bg, _graph_dir(), name=f"{len(_GRAPH_CACHE):03d}_{BLOCK_FILE_NAME}"
        )
    return _GRAPH_CACHE[key]


def _partition(g, n_blocks: int):
    return _as_backend(partition_into_n_blocks(g, n_blocks))


def _row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def _us_per_step(res) -> float:
    return 1e6 * res.stats.sim_wall_time / max(res.stats.steps_sampled, 1)


def _default_graph():
    return erdos_renyi(N_V, N_E, seed=1)


def fig1_profile() -> list[str]:
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    rows = []
    for name, task in (
        ("deepwalk", deepwalk_task(walks_per_vertex=WALKS_PV, length=LENGTH)),
        ("node2vec", rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH)),
    ):
        res = SOGWEngine(bg, task, **POOL_KW).run()
        s = res.stats
        total = max(s.sim_wall_time, 1e-12)
        rows.append(_row(
            f"fig1_sogw_{name}", _us_per_step(res),
            f"vertex_io_frac={s.sim_vertex_io_time/total:.3f};"
            f"block_io_frac={s.sim_block_io_time/total:.3f}",
        ))
    return rows


def table3_engines() -> list[str]:
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    rows = []
    for tname, task in (
        ("rwnv", rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH)),
        ("prnv", prnv_task(3, g.num_vertices, samples_per_vertex=1)),
    ):
        r_pb = PlainBucketEngine(bg, task, **POOL_KW).run()
        r_bb = BiBlockEngine(bg, task, **POOL_KW).run()
        rows.append(_row(
            f"table3_{tname}_biblock_vs_pb", _us_per_step(r_bb),
            f"wall_ratio={r_bb.stats.sim_wall_time/r_pb.stats.sim_wall_time:.3f};"
            f"blockio_ratio={r_bb.stats.block_ios/max(r_pb.stats.block_ios,1):.3f}",
        ))
    return rows


def table4_loading() -> list[str]:
    g = _default_graph()
    rows = []
    parts = {"seq": _partition(g, N_BLOCKS)}
    _, loc, _ = greedy_locality_partition(g, N_BLOCKS, rounds=2)
    parts["metis_like"] = _as_backend(loc)
    task = rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH)
    for pname, bg in parts.items():
        r_full = BiBlockEngine(bg, task, loading="full", **POOL_KW).run()
        r_auto = BiBlockEngine(bg, task, loading="auto", **POOL_KW).run()
        rows.append(_row(
            f"table4_{pname}_learning_vs_full", _us_per_step(r_auto),
            f"wall_ratio={r_auto.stats.sim_wall_time/r_full.stats.sim_wall_time:.3f};"
            f"blockio={r_auto.stats.block_ios};full_blockio={r_full.stats.block_ios};"
            f"ondemand_ios={r_auto.stats.ondemand_ios};edge_cut={bg.edge_cut():.3f}",
        ))
    return rows


def table6_distributions() -> list[str]:
    n = int(1200 * SCALE)
    graphs = {
        "circulant": circulant_graph(n, 8),
        "random": erdos_renyi(n, n * 8, seed=2),
        "basf": barabasi_albert(n, 8, seed=2),
        "sbm": stochastic_block_model([n // 4] * 4, 0.02, 0.002, seed=2),
    }
    rows = []
    task_len = max(LENGTH // 2, 8)
    for gname, g in graphs.items():
        bg = _partition(g, N_BLOCKS)
        task = rwnv_task(walks_per_vertex=WALKS_PV, length=task_len)
        r_so = SOGWEngine(bg, task, **POOL_KW).run()
        r_sg = SOGWEngine(bg, task, static_cache=True, **POOL_KW).run()
        r_bb = BiBlockEngine(bg, task, **POOL_KW).run()
        rows.append(_row(
            f"table6_{gname}", _us_per_step(r_bb),
            f"speedup_vs_sogw={r_so.stats.sim_wall_time/max(r_bb.stats.sim_wall_time,1e-12):.2f};"
            f"speedup_vs_sgsc={r_sg.stats.sim_wall_time/max(r_bb.stats.sim_wall_time,1e-12):.2f}",
        ))
    return rows


def table7_first_order() -> list[str]:
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    task = deepwalk_task(walks_per_vertex=WALKS_PV, length=LENGTH)
    # GraphWalker baseline = SOGW machinery on a 1st-order model (no
    # previous-vertex I/O is charged because the model never needs it)
    r_gw = SOGWEngine(bg, task, **POOL_KW).run()
    r_nl = BiBlockEngine(bg, task, loading="full", **POOL_KW).run()
    r_gr = BiBlockEngine(bg, task, loading="auto", **POOL_KW).run()

    def _ratios(r):
        return (
            f"blockio_ratio_vs_gw={r.stats.sim_block_io_time/max(r_gw.stats.sim_block_io_time,1e-12):.3f};"
            f"simio_ratio_vs_gw={r.stats.sim_io_time/max(r_gw.stats.sim_io_time,1e-12):.3f}"
        )

    return [
        _row("table7_graphwalker", _us_per_step(r_gw),
             f"blockio_s={r_gw.stats.sim_block_io_time:.4f};block_ios={r_gw.stats.block_ios}"),
        _row("table7_grasorw_no_lbl", _us_per_step(r_nl), _ratios(r_nl)),
        _row("table7_grasorw", _us_per_step(r_gr), _ratios(r_gr)),
    ]


def table8_scheduling() -> list[str]:
    from repro.core import make_scheduler

    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    rows = []
    task = deepwalk_task(walks_per_vertex=WALKS_PV, length=LENGTH)
    for strat in ("alphabet", "iteration", "min_height", "max_sum", "graphwalker"):
        eng = SOGWEngine(bg, task, **POOL_KW)
        eng.scheduler = make_scheduler(strat, bg.num_blocks, 0)
        res = eng.run()
        rows.append(_row(
            f"table8_{strat}", _us_per_step(res),
            f"block_ios={res.stats.block_ios};"
            f"blockio_s={res.stats.sim_block_io_time:.4f}",
        ))
    return rows


def fig8_end_to_end() -> list[str]:
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    rows = []
    for tname, task in (
        ("rwnv", rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH)),
        ("prnv", prnv_task(5, g.num_vertices, samples_per_vertex=1)),
    ):
        r_so = SOGWEngine(bg, task, **POOL_KW).run()
        r_sg = SOGWEngine(bg, task, static_cache=True, **POOL_KW).run()
        r_bb = BiBlockEngine(bg, task, **POOL_KW).run()
        rows.append(_row(
            f"fig8_{tname}_grasorw", _us_per_step(r_bb),
            f"speedup_vs_sogw={r_so.stats.sim_wall_time/max(r_bb.stats.sim_wall_time,1e-12):.2f};"
            f"speedup_vs_sgsc={r_sg.stats.sim_wall_time/max(r_bb.stats.sim_wall_time,1e-12):.2f};"
            f"io_reduction={r_so.stats.sim_io_time/max(r_bb.stats.sim_io_time,1e-12):.2f}",
        ))
    return rows


def pool_backends() -> list[str]:
    """The storage-layer axis: memory vs disk walk pools, prefetch on/off.

    Both backends run at the SAME flush threshold, so their rows differ
    only in where spilled bytes go (modelled vs real files) — the charged
    I/O is identical by construction.  The prefetch benefit is reported as
    ``mat_stall``: wall time ``BlockStore.get`` stalled the critical path
    materialising a block (sync materialisation + waiting on an unfinished
    prefetch).  With prefetch on, materialisation overlaps the jitted
    advance call and the stall should shrink toward zero.
    """
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    task = rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH)
    BiBlockEngine(bg, task).run()  # warm the jit cache off the clock
    rows = []
    for pool in ("memory", "disk"):
        kw: Dict[str, object] = {"pool": pool, "pool_flush_walks": 256}
        res = BiBlockEngine(bg, task, **kw).run()
        off = BiBlockEngine(bg, task, prefetch=False, **kw).run()
        c = res.block_store_counters
        stall_on = c["sync_materialize_time"] + c["prefetch_wait_time"]
        stall_off = off.block_store_counters["sync_materialize_time"]
        rows.append(_row(
            f"pool_{pool}_biblock", _us_per_step(res),
            f"prefetch_hits={c['prefetch_hits']};"
            f"prefetch_issued={c['prefetch_issued']};"
            f"cache_hits={c['cache_hits']};"
            f"walk_bytes_written={res.stats.walk_bytes_written};"
            f"mat_stall_ms={1e3*stall_on:.2f};"
            f"mat_stall_noprefetch_ms={1e3*stall_off:.2f}",
        ))
    return rows


def ondemand_exec() -> list[str]:
    """Activated-subgraph execution: on-demand buckets run on compacted
    :class:`~repro.core.graph.BlockView`\\ s instead of fully-materialised
    blocks, so the device-resident footprint shrinks.

    On a skewed (Barabasi-Albert) graph, a PPR query burst (few walks
    relative to block size — the paper's regime where block loads become
    light vertex I/Os, §5/§7.8) runs with ``loading="full"`` and
    ``loading="ondemand"`` and *asserts* that

    * the walks are bit-identical (endpoint histogram CRC), and
    * ``IOStats.peak_resident_bytes`` is strictly lower for on-demand —

    the acceptance criterion that on-demand loading is no longer
    larger-than-memory in accounting only.
    """
    from repro.core.transition import Node2vec, WalkTask

    n = max(int(3000 * SCALE), 600)
    g = barabasi_albert(n, 8, seed=2)
    bg = _partition(g, 10)
    task = WalkTask(
        Node2vec(p=2.0, q=0.5), length=20,
        query_vertex=5, total_walks=512, decay=0.85, seed=9,
    )
    BiBlockEngine(bg, task, **POOL_KW).run()  # warm the jit cache off the clock
    r_full = BiBlockEngine(bg, task, loading="full", **POOL_KW).run()
    r_od = BiBlockEngine(bg, task, loading="ondemand", **POOL_KW).run()
    crc_f = zlib.crc32(np.ascontiguousarray(r_full.endpoint_counts).tobytes())
    crc_o = zlib.crc32(np.ascontiguousarray(r_od.endpoint_counts).tobytes())
    assert crc_f == crc_o, (
        f"on-demand execution changed the walks: endpoint crc {crc_o:#010x} "
        f"!= full-load {crc_f:#010x}"
    )
    pf = r_full.stats.peak_resident_bytes
    po = r_od.stats.peak_resident_bytes
    assert po < pf, (
        f"expected a strictly lower resident peak for on-demand execution, "
        f"got {po} >= {pf}"
    )
    # loader_summary is reported uniformly (None only for engines without
    # a learning-based loader) — the JSON report can always include it
    eta0 = (r_od.loader_summary or {}).get("global_eta0")
    return [
        _row("ondemand_exec_full", _us_per_step(r_full),
             f"peak_resident_bytes={pf};endpoint_crc={crc_f:#010x}"),
        _row("ondemand_exec_ondemand", _us_per_step(r_od),
             f"peak_resident_bytes={po};peak_ratio={po / pf:.3f};"
             f"ondemand_ios={r_od.stats.ondemand_ios};eta0={eta0};"
             f"endpoint_crc={crc_o:#010x}"),
    ]


def coalesced_io() -> list[str]:
    """The gap-aware on-demand read planner (:mod:`repro.io.ioplan`) vs the
    per-vertex reference reads.

    Runs the ``ondemand_exec`` PPR burst on the same skewed BA graph at
    ``io_coalesce_gap`` in {0 (reference), 4 KiB, 64 KiB} and *asserts*

    * the walks are bit-identical at every gap (endpoint histogram CRC),
    * charged useful bytes (``ondemand_bytes``) are identical — coalescing
      moves extra bytes, it never charges them as useful,
    * ``ondemand_syscalls`` is strictly below the reference at every gap
      and at least 4x lower at the 64 KiB budget —

    the acceptance criterion that the planner turns Fig. 5(b)'s four tiny
    preads per vertex into a handful of ranged reads without touching the
    paper's accounting.  The us column is the same per-step derivation the
    ``ondemand_exec`` scoreboard rows use (steps are identical across gaps,
    so the denominator is constant): the per-seek cost term's drop shows up
    directly against the ~536 us/call reference baseline.
    """
    from repro.core.transition import Node2vec, WalkTask

    n = max(int(3000 * SCALE), 600)
    g = barabasi_albert(n, 8, seed=2)
    bg = _partition(g, 10)
    # denser burst than ondemand_exec's (same graph/partition, so the disk
    # container is shared): coalescing wins scale with activated density
    task = WalkTask(
        Node2vec(p=2.0, q=0.5), length=20,
        query_vertex=5, total_walks=2048, decay=0.85, seed=9,
    )
    BiBlockEngine(bg, task, loading="ondemand", **POOL_KW).run()  # warm jit
    rows, results = [], {}
    try:
        for gap in (0, 4096, 65536):
            bg.io_coalesce_gap = gap
            results[gap] = BiBlockEngine(bg, task, loading="ondemand", **POOL_KW).run()
    finally:
        # the graph object is shared across bench entries (content-keyed
        # container cache) — leave it in the reference configuration
        bg.io_coalesce_gap = 0
    ref = results[0]
    crc_ref = zlib.crc32(np.ascontiguousarray(ref.endpoint_counts).tobytes())
    ref_sys = ref.stats.ondemand_syscalls
    rows.append(_row(
        "coalesced_io_gap_0", _us_per_step(ref),
        f"ondemand_syscalls={ref_sys};coalesced_ranges={ref.stats.coalesced_ranges};"
        f"coalesce_waste_bytes={ref.stats.coalesce_waste_bytes};"
        f"ondemand_bytes={ref.stats.ondemand_bytes};endpoint_crc={crc_ref:#010x}",
    ))
    for gap in (4096, 65536):
        r = results[gap]
        s = r.stats
        crc = zlib.crc32(np.ascontiguousarray(r.endpoint_counts).tobytes())
        assert crc == crc_ref, (
            f"read coalescing changed the walks at gap={gap}: endpoint crc "
            f"{crc:#010x} != reference {crc_ref:#010x}"
        )
        assert s.ondemand_bytes == ref.stats.ondemand_bytes, (
            f"charged useful bytes changed at gap={gap}: "
            f"{s.ondemand_bytes} != {ref.stats.ondemand_bytes}"
        )
        assert s.ondemand_syscalls < ref_sys, (
            f"expected strictly fewer on-demand syscalls at gap={gap}, got "
            f"{s.ondemand_syscalls} >= {ref_sys}"
        )
        rows.append(_row(
            f"coalesced_io_gap_{gap}", _us_per_step(r),
            f"ondemand_syscalls={s.ondemand_syscalls};"
            f"syscall_reduction={ref_sys / max(s.ondemand_syscalls, 1):.2f};"
            f"coalesced_ranges={s.coalesced_ranges};"
            f"coalesce_waste_bytes={s.coalesce_waste_bytes};"
            f"endpoint_crc={crc:#010x}",
        ))
    big = results[65536].stats.ondemand_syscalls
    assert ref_sys >= 4 * big, (
        f"expected a >=4x syscall reduction at the 64 KiB budget, got "
        f"{ref_sys} / {big} = {ref_sys / max(big, 1):.2f}x"
    )
    return rows


def backend_matrix() -> list[str]:
    """CI bench-smoke: the full pool x graph backend matrix on a tiny graph.

    Runs BiBlockEngine at every ``(pool, graph)`` combination and *asserts*
    the deterministic ``IOStats`` signature (block/on-demand/walk counters
    plus a CRC of the endpoint histogram) is identical across all four —
    the acceptance criterion that real file I/O never changes the paper's
    accounting.  Disk rows additionally report the real bytes that moved
    through the container's file descriptor.
    """
    n = max(int(600 * SCALE), 200)
    g = erdos_renyi(n, n * 8, seed=3)
    bg_ram = partition_into_n_blocks(g, 4)
    task = rwnv_task(walks_per_vertex=2, length=10, seed=9)
    BiBlockEngine(bg_ram, task).run()  # warm the jit cache off the clock

    from repro.io import BLOCK_FILE_NAME, DiskBlockedGraph, write_block_file

    path = os.path.join(_graph_dir(), f"matrix_{BLOCK_FILE_NAME}")
    write_block_file(bg_ram, path)

    rows, base_sig = [], None
    for pool in ("memory", "disk"):
        for gname in ("ram", "disk"):
            bg = bg_ram if gname == "ram" else DiskBlockedGraph(path)
            res = BiBlockEngine(bg, task, pool=pool, pool_flush_walks=32).run()
            s = res.stats
            sig = (
                s.block_ios, s.block_bytes, s.ondemand_ios, s.ondemand_bytes,
                s.steps_sampled, s.walk_bytes_written, s.walk_bytes_read,
                zlib.crc32(np.ascontiguousarray(res.endpoint_counts).tobytes()),
            )
            if base_sig is None:
                base_sig = sig
            assert sig == base_sig, (
                f"IOStats diverged for pool={pool} graph={gname}: "
                f"{sig} != {base_sig}"
            )
            real = ""
            if gname == "disk":
                c = bg.counters()
                real = (f";file_data_bytes_read={c['data_bytes_read']}"
                        f";file_full_loads={c['full_loads']}")
            rows.append(_row(
                f"matrix_pool_{pool}_graph_{gname}", _us_per_step(res),
                f"block_ios={s.block_ios};block_bytes={s.block_bytes};"
                f"walk_bytes_written={s.walk_bytes_written};"
                f"endpoint_crc={sig[-1]:#010x}{real}",
            ))
            if gname == "disk":
                bg.close()
    rows.append(_row("matrix_identical", 0.0,
                     f"combos=4;signature_fields={len(base_sig)};ok=1"))
    return rows


def pipeline_overlap() -> list[str]:
    """The staged async bi-block pipeline vs the serial reference mode.

    Runs the same RWNV workload with ``async_pipeline=True`` (default:
    walk-pool writer thread + next-slot pool drain/bucket split preloads +
    plan-driven view prefetches) and ``async_pipeline=False`` (every stage
    inline on the critical path) and *asserts*

    * the walks are bit-identical (endpoint histogram CRC),
    * the async run overlapped real load bytes
      (``IOStats.overlapped_load_bytes > 0``) — and strictly more of them
      than the serial run's pre-existing prefetch-thread hits, so the
      pipeline's own stages (pool preloads, next-slot view prefetch)
      demonstrably contributed, and
    * the async run's ``pipeline_stall_slots`` (slots whose pool load ran
      synchronously because no preload was in flight) is strictly below the
      serial run's slot count —

    the acceptance criterion that the overlap is measured, not vibes.  Both
    gauges are deterministic: they count *what was scheduled off the
    critical path* (enqueue order), not thread timing.
    """
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    task = rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH, seed=5)
    # a small flush threshold makes walk spills (and their preloaded
    # read-back) part of the measured overlap
    kw: Dict[str, object] = dict(POOL_KW, pool_flush_walks=256)
    BiBlockEngine(bg, task, **kw).run()  # warm the jit cache off the clock
    r_async = BiBlockEngine(bg, task, **kw).run()
    r_serial = BiBlockEngine(bg, task, async_pipeline=False, **kw).run()
    crc_a = zlib.crc32(np.ascontiguousarray(r_async.endpoint_counts).tobytes())
    crc_s = zlib.crc32(np.ascontiguousarray(r_serial.endpoint_counts).tobytes())
    assert crc_a == crc_s, (
        f"async pipeline changed the walks: endpoint crc {crc_a:#010x} "
        f"!= serial {crc_s:#010x}"
    )
    sa, ss = r_async.stats, r_serial.stats
    assert sa.overlapped_load_bytes > 0, "async pipeline overlapped no load bytes"
    assert sa.overlapped_load_bytes > ss.overlapped_load_bytes, (
        f"pipeline stages added no overlap beyond the serial prefetch thread: "
        f"{sa.overlapped_load_bytes} <= {ss.overlapped_load_bytes}"
    )
    assert sa.pipeline_stall_slots < ss.time_slots, (
        f"async pipeline stalled every slot: {sa.pipeline_stall_slots} "
        f">= {ss.time_slots}"
    )
    return [
        _row("pipeline_async", _us_per_step(r_async),
             f"overlapped_load_bytes={sa.overlapped_load_bytes};"
             f"stall_slots={sa.pipeline_stall_slots};"
             f"time_slots={sa.time_slots};"
             f"writer_queue_peak={sa.writer_queue_peak};"
             f"endpoint_crc={crc_a:#010x}"),
        _row("pipeline_serial", _us_per_step(r_serial),
             f"overlapped_load_bytes={ss.overlapped_load_bytes};"
             f"stall_slots={ss.pipeline_stall_slots};"
             f"time_slots={ss.time_slots};"
             f"endpoint_crc={crc_s:#010x}"),
    ]


def sharded_pool() -> list[str]:
    """Sharded walk pools: the PR-4 sequenced writer generalised to one
    writer per keyspace shard.

    Runs the same RWNV workload with ``pool_shards`` in {1, 2, 4, 8} (1 ==
    the single AsyncWalkPool writer) and *asserts*

    * the walks are bit-identical (endpoint histogram CRC) at every shard
      count,
    * the deterministic I/O charges — block, on-demand, AND walk spill
      bytes — are invariant across shard counts (a block's op stream lands
      on exactly one shard in program order, so its spill points cannot
      move),
    * with >= 2 shards the spills really were partitioned: the per-shard
      breakdown ``IOStats.shard_spill_bytes`` names >= 2 shards and sums
      to ``walk_bytes_written`` exactly, and
    * the breakdown (and the ``shard_imbalance`` gauge) is deterministic —
      a repeat run reproduces it bit-for-bit.  No timing-dependent
      quantity (queue peaks, thread interleavings) is part of any
      asserted signature.
    """
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    task = rwnv_task(walks_per_vertex=WALKS_PV, length=LENGTH, seed=13)
    # a low flush threshold makes every pool-owning block spill, so the
    # per-shard breakdown has real bytes to partition
    kw: Dict[str, object] = dict(POOL_KW, pool_flush_walks=64)
    BiBlockEngine(bg, task, **kw).run()  # warm the jit cache off the clock
    rows, base_sig = [], None
    for shards in (1, 2, 4, 8):
        res = BiBlockEngine(bg, task, pool_shards=shards, **kw).run()
        s = res.stats
        crc = zlib.crc32(np.ascontiguousarray(res.endpoint_counts).tobytes())
        sig = (
            crc, s.steps_sampled, s.block_ios, s.block_bytes,
            s.ondemand_ios, s.ondemand_bytes,
            s.walk_bytes_written, s.walk_bytes_read,
        )
        if base_sig is None:
            base_sig = sig
        assert sig == base_sig, (
            f"sharding changed the walks or charges at pool_shards={shards}: "
            f"{sig} != {base_sig}"
        )
        spills = dict(s.shard_spill_bytes)
        if shards >= 2:
            assert len(spills) >= 2, (
                f"pool_shards={shards} spilled through {len(spills)} shard "
                f"writer(s) — no real partition of the persist path"
            )
            assert sum(spills.values()) == s.walk_bytes_written, (
                f"per-shard spill breakdown {spills} does not sum to "
                f"walk_bytes_written={s.walk_bytes_written}"
            )
            again = BiBlockEngine(bg, task, pool_shards=shards, **kw).run().stats
            assert dict(again.shard_spill_bytes) == spills, (
                f"shard spill breakdown is not deterministic: "
                f"{dict(again.shard_spill_bytes)} != {spills}"
            )
            assert again.shard_imbalance == s.shard_imbalance, (
                f"shard_imbalance is not deterministic: "
                f"{again.shard_imbalance} != {s.shard_imbalance}"
            )
        rows.append(_row(
            f"sharded_pool_{shards}", _us_per_step(res),
            f"endpoint_crc={crc:#010x};walk_bytes_written={s.walk_bytes_written};"
            f"spill_shards={len(spills)};shard_imbalance={s.shard_imbalance:.3f};"
            f"overlapped_load_bytes={s.overlapped_load_bytes}",
        ))
    return rows


def fused_advance() -> list[str]:
    """The fused Pallas multi-hop advance vs the plain jitted JAX advance.

    Runs the same RWNV workload under ``advance_impl="jax"`` and
    ``advance_impl="pallas"`` (interpret mode on CPU CI; Mosaic on TPU),
    *asserts* the walks are bit-identical (endpoint histogram CRC + step
    count + deterministic I/O charges — the kernel draws the very same
    counter-keyed threefry uniforms), and reports ``us_per_call`` for both
    so the report tracks the fused kernel's speed against the default path.
    """
    g = _default_graph()
    bg = _partition(g, N_BLOCKS)
    task = rwnv_task(p=2.0, q=0.5, walks_per_vertex=WALKS_PV, length=LENGTH, seed=17)
    rows, base_sig = [], None
    for impl in ("jax", "pallas"):
        kw: Dict[str, object] = dict(POOL_KW, advance_impl=impl)
        BiBlockEngine(bg, task, **kw).run()  # warm the jit cache off the clock
        res = BiBlockEngine(bg, task, **kw).run()
        s = res.stats
        crc = zlib.crc32(np.ascontiguousarray(res.endpoint_counts).tobytes())
        sig = (
            crc, s.steps_sampled, s.block_ios, s.block_bytes,
            s.ondemand_ios, s.ondemand_bytes,
        )
        if base_sig is None:
            base_sig = sig
        assert sig == base_sig, (
            f"advance_impl={impl} changed the walks or charges: {sig} != {base_sig}"
        )
        rows.append(_row(
            f"fused_advance_{impl}", _us_per_step(res),
            f"endpoint_crc={crc:#010x};steps={s.steps_sampled};"
            f"exec_s={s.exec_time:.3f}",
        ))
    return rows


def query_serving() -> list[str]:
    """The serving front end: skewed point queries vs the batch tier.

    Submits a skewed query mix (most sources in the hottest block of a
    Barabasi-Albert graph) to two :class:`repro.serve.WalkQueryServer`\\ s —
    one with the hot-set policy pinning 2 blocks, one pure-LRU
    (``hot_blocks=0``) — and *asserts*

    * both servers produce identical answers (pinning changes what is
      charged, never what executes),
    * every admission batch's walks are bit-identical to the equivalent
      direct batch run (same engine, task seed ``server.batch_seed(k)``,
      ``initial_walks`` = the batch's concatenated sources) — endpoint
      histogram CRC per batch, and
    * the hot-set server's ``block_load`` charges are *strictly* below the
      pure-LRU server's on this mix —

    the acceptance criteria that serving rides the batch machinery
    unchanged and the hot set is a real I/O saving, not an accounting
    trick.  Derived fields report the per-query latency percentiles
    (p50/p95/p99, wall clock) and the pinning ledger.
    """
    from repro.serve import QueryConfig, WalkQueryServer

    n = max(int(3000 * SCALE), 600)
    g = barabasi_albert(n, 8, seed=2)
    bg = _partition(g, 10)
    config = QueryConfig(p=1.0, q=1.0, length=10, decay=0.85, samples=32)
    n_queries, max_batch = 96, 32
    # BA hubs live at the low ids: block 0 is the hot block of the mix
    rng = np.random.default_rng(7)
    hot_lo, hot_hi = int(bg.block_starts[0]), int(bg.block_starts[1])
    sources = np.where(
        rng.random(n_queries) < 0.85,
        rng.integers(hot_lo, hot_hi, n_queries),
        rng.integers(0, n, n_queries),
    ).astype(np.int64)

    def serve(hot_blocks: int):
        server = WalkQueryServer(
            bg, max_batch=max_batch, hot_blocks=hot_blocks, seed=21, **POOL_KW
        )
        with server:
            for s in sources:
                server.submit(int(s), config)
            answers = server.flush()
            return server, answers

    serve(2)  # warm the jit cache off the clock
    hot, hot_ans = serve(2)
    lru, lru_ans = serve(0)
    assert len(hot_ans) == len(lru_ans) == n_queries
    for a, b in zip(hot_ans, lru_ans):
        assert np.array_equal(a.vertices, b.vertices) and np.array_equal(
            a.counts, b.counts
        ), f"hot-set pinning changed the answer of query {a.qid}"
    # CRC identity: each admission batch vs its equivalent direct batch run
    for k in range(hot.batches_served):
        batch = hot_ans[k * max_batch : (k + 1) * max_batch]
        served = np.zeros(n, np.int64)
        for a in batch:
            served += a.dense_counts(n)
        direct = BiBlockEngine(
            bg,
            config.task(hot.batch_seed(k)),
            initial_walks=np.repeat([a.source for a in batch], config.samples),
            **POOL_KW,
        ).run()
        crc_s = zlib.crc32(np.ascontiguousarray(served).tobytes())
        crc_d = zlib.crc32(np.ascontiguousarray(direct.endpoint_counts).tobytes())
        assert crc_s == crc_d, (
            f"served batch {k} diverged from the direct run: "
            f"endpoint crc {crc_s:#010x} != {crc_d:#010x}"
        )
    sh, sl = hot.stats, lru.stats
    assert sh.pinned_block_hits > 0, "hot-set policy never served a pinned hit"
    assert sh.block_ios < sl.block_ios, (
        f"hot-set pinning saved no block loads: {sh.block_ios} >= {sl.block_ios}"
    )
    lat_h, lat_l = hot.latency_summary(), lru.latency_summary()

    def _lat(lat):
        return (f"p50_ms={lat['p50'] * 1e3:.2f};p95_ms={lat['p95'] * 1e3:.2f};"
                f"p99_ms={lat['p99'] * 1e3:.2f}")

    return [
        _row("query_serving_hotset", 0.0,
             f"queries={n_queries};batches={hot.batches_served};{_lat(lat_h)};"
             f"block_ios={sh.block_ios};pinned_blocks={sh.hot_pinned_blocks};"
             f"pinned_hits={sh.pinned_block_hits};"
             f"pinned_bytes_saved={sh.pinned_bytes_saved}"),
        _row("query_serving_lru", 0.0,
             f"queries={n_queries};batches={lru.batches_served};{_lat(lat_l)};"
             f"block_ios={sl.block_ios};"
             f"blockio_saving={1.0 - sh.block_ios / max(sl.block_ios, 1):.3f}"),
    ]


ALL: Dict[str, Callable[[], list[str]]] = {
    "fig1_profile": fig1_profile,
    "table3_engines": table3_engines,
    "table4_loading": table4_loading,
    "table6_distributions": table6_distributions,
    "table7_first_order": table7_first_order,
    "table8_scheduling": table8_scheduling,
    "fig8_end_to_end": fig8_end_to_end,
    "pool_backends": pool_backends,
    "ondemand_exec": ondemand_exec,
    "coalesced_io": coalesced_io,
    "backend_matrix": backend_matrix,
    "pipeline_overlap": pipeline_overlap,
    "sharded_pool": sharded_pool,
    "fused_advance": fused_advance,
    "query_serving": query_serving,
}


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", help=f"entries to run (default all): {sorted(ALL)}")
    ap.add_argument("--pool", choices=("memory", "disk"), default=None,
                    help="walk-pool backend for every engine run")
    ap.add_argument("--flush-walks", type=int, default=None,
                    help="pool spill threshold (disk backend)")
    ap.add_argument("--graph-backend", choices=("ram", "disk"), default=None,
                    help="graph-block backend for every engine run")
    ap.add_argument("--graph-dir", default=None,
                    help="directory for packed block files (disk graph backend)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON report (CI artifact)")
    args = ap.parse_args(argv)
    if args.pool or args.flush_walks is not None:
        set_pool_backend(args.pool or str(POOL_KW["pool"]), args.flush_walks)
    if args.graph_backend:
        set_graph_backend(args.graph_backend, args.graph_dir)
    print("name,us_per_call,derived")
    all_rows = []
    for name in args.names or list(ALL):
        for row in ALL[name]():
            print(row, flush=True)
            all_rows.append(row)
    if args.json:
        report = {
            "config": {
                "scale": SCALE,
                "pool": POOL_KW["pool"],
                "pool_flush_walks": POOL_KW["pool_flush_walks"],
                "graph_backend": GRAPH_KW["backend"],
            },
            "rows": [
                dict(zip(("name", "us_per_call", "derived"), r.split(",", 2)))
                for r in all_rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
