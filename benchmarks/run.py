"""Benchmark entry point: ``python -m benchmarks.run [names...]``.

Prints ``name,us_per_call,derived`` CSV (one row per paper-table entry).
Env: BENCH_SCALE=0.5 shrinks the graphs for quick runs.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import bench_lm, bench_walks

    wanted = set(sys.argv[1:])
    print("name,us_per_call,derived")
    for name, fn in bench_walks.ALL.items():
        if wanted and name not in wanted:
            continue
        for row in fn():
            print(row, flush=True)
    if not wanted or "lm" in wanted:
        for row in bench_lm.walk_kernel_throughput():
            print(row, flush=True)
        for row in bench_lm.lm_steps():
            print(row, flush=True)


if __name__ == "__main__":
    main()
