"""Benchmark entry point: ``python -m benchmarks.run [names...] [--pool disk]``.

Prints ``name,us_per_call,derived`` CSV (one row per paper-table entry).
Env: BENCH_SCALE=0.5 shrinks the graphs for quick runs; BENCH_POOL=disk
selects the disk walk-pool backend (same as ``--pool disk``).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import bench_lm, bench_walks

    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*")
    ap.add_argument("--pool", choices=("memory", "disk"), default=None,
                    help="walk-pool backend for the walk benchmarks")
    ap.add_argument("--flush-walks", type=int, default=None)
    args = ap.parse_args()
    if args.pool or args.flush_walks is not None:
        bench_walks.set_pool_backend(
            args.pool or str(bench_walks.POOL_KW["pool"]), args.flush_walks)

    wanted = set(args.names)
    print("name,us_per_call,derived")
    for name, fn in bench_walks.ALL.items():
        if wanted and name not in wanted:
            continue
        for row in fn():
            print(row, flush=True)
    if not wanted or "lm" in wanted:
        for row in bench_lm.walk_kernel_throughput():
            print(row, flush=True)
        for row in bench_lm.lm_steps():
            print(row, flush=True)


if __name__ == "__main__":
    main()
