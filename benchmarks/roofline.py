"""Roofline analysis from compiled dry-run HLO (deliverable g).

`compiled.cost_analysis()` on this JAX/XLA build reports per-device totals
but counts every `while` (scan) body ONCE — useless for scanned layer
stacks.  This module parses the optimized post-SPMD HLO text instead:

  * per-computation symbol tables (instruction -> dtype/shape),
  * dot FLOPs from result shape x contracted dims (lhs shape),
  * collective bytes with ring-algorithm multipliers and replica-group
    sizes parsed from the op,
  * memory-traffic proxy: bytes crossing fusion boundaries (fusion/dot/
    custom-call operands + outputs — the materialisation points),
  * `while` bodies multiplied by their trip count, which XLA leaves as the
    inline `constant(N)` in each loop condition (verified on this build);
    nested loops multiply through the call chain.

Terms (v5e): compute = FLOPs / 197e12, memory = bytes / 819e9,
collective = bytes / 50e9 — all per chip, seconds.
"""

from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["HW", "parse_hlo", "analyze_hlo", "roofline_terms", "model_flops"]

HW = {
    "flops_bf16": 197e12,  # TPU v5e peak bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# type may be a tuple containing `/*index=N*/` comments; opcode is the first
# bare `word(` token after the type (no parens occur inside type strings)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^\n]*\))?\s*->[^\n]*\{\s*$|^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^\n]*\)\s*\{\s*$",
    re.M,
)


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes (raw tail of the line)

    def operands(self) -> List[str]:
        # operand names up to the closing paren of the op
        depth = 0
        end = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        args = self.rest[:end]
        return re.findall(r"%([\w.\-]+)", args)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]

    def table(self) -> Dict[str, str]:
        return {i.name: i.type_str for i in self.instrs}


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        if not line.startswith(" ") and stripped.endswith("{"):
            # computation header: "%name (params) -> type {" or "ENTRY ..."
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                current = Computation(m.group(1), [])
                comps[current.name] = current
            continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.instrs.append(Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _dot_flops(instr: Instr, table: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.type_str) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    ops = instr.operands()
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if m and ops:
        lhs_dims = _shape_dims(table.get(ops[0], "")) or []
        for idx in (int(x) for x in m.group(1).split(",") if x):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out_n * contract


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.mem_bytes += o.mem_bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(
            self.flops * f, self.mem_bytes * f, self.coll_bytes * f,
            {k: v * f for k, v in self.coll_by_op.items()},
        )


def _fusion_read_bytes(fc: Computation, instr: Instr, table: Dict[str, str]) -> int:
    """Bytes a fusion actually reads: parameters consumed only through
    dynamic-slice count as the slice size, not the full operand (scan
    bodies address stacked [L, ...] arrays this way)."""
    operands = instr.operands()
    # parameter index -> slice-only read size
    param_instrs = [i for i in fc.instrs if i.opcode == "parameter"]
    users: Dict[str, List[Instr]] = {p.name: [] for p in param_instrs}
    for i in fc.instrs:
        for o in i.operands():
            if o in users:
                users[o].append(i)
    total = 0
    for p in param_instrs:
        mm = re.match(r"(\d+)\)", p.rest)
        idx = int(mm.group(1)) if mm else None
        full = _shape_bytes(p.type_str)
        if idx is not None and idx < len(operands):
            full = _shape_bytes(table.get(operands[idx], p.type_str)) or full
        uses = users.get(p.name, [])
        if uses and all(u.opcode == "dynamic-slice" for u in uses):
            total += sum(_shape_bytes(u.type_str) for u in uses)
        else:
            total += full
    return total


def _convert_factor(
    instr: Instr, comp: Computation, comps: Dict[str, Computation]
) -> float:
    """If this collective's operand is an upcast (convert bf16->f32, either
    bare or as a convert-only fusion), return the byte ratio (<1) of the
    logical dtype — undoing the XLA:CPU f32-dot-upcast artifact."""
    instr_by_name = {i.name: i for i in comp.instrs}
    ops = instr.operands()
    if not ops:
        return 1.0
    src = instr_by_name.get(ops[0])
    if src is None:
        return 1.0
    out_dt = _SHAPE_RE.search(src.type_str or instr.type_str)
    out_bytes = _DTYPE_BYTES.get(out_dt.group(1), 4) if out_dt else 4
    in_bytes = None
    if src.opcode == "convert":
        inner = instr_by_name.get(src.operands()[0]) if src.operands() else None
        if inner is not None:
            m = _SHAPE_RE.search(inner.type_str)
            if m:
                in_bytes = _DTYPE_BYTES.get(m.group(1))
    elif src.opcode == "fusion" and "convert" in src.name:
        m = re.search(r"calls=%([\w.\-]+)", src.rest)
        fc = comps.get(m.group(1)) if m else None
        if fc is not None:
            big = []
            for p in fc.instrs:
                if p.opcode != "parameter":
                    continue
                sm = _SHAPE_RE.search(p.type_str)
                if sm and len(_shape_dims(p.type_str) or []) >= 2:
                    big.append(_DTYPE_BYTES.get(sm.group(1), 4))
            if big:
                in_bytes = min(big)
    if in_bytes and in_bytes < out_bytes:
        return in_bytes / out_bytes
    # hoisted-convert case: XLA:CPU converts the stacked bf16 weights to f32
    # once outside the loop and gathers f32 inside.  Any f32 collective whose
    # op_name attributes it to a dot_general would be bf16 on TPU (MXU dots
    # take bf16 operands natively).
    if out_bytes == 4 and "dot_general" in instr.rest:
        return 0.5
    return 1.0


def _trip_count(cond: Computation) -> int:
    """XLA leaves the loop bound as an inline constant in the condition."""
    consts = []
    for i in cond.instrs:
        if i.opcode == "constant":
            m = re.match(r"(\d+)\)", i.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _comp_cost(
    comp: Computation, comps: Dict[str, Computation], memo: Dict[str, Cost],
    n_devices: int,
) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()  # cycle guard
    total = Cost()
    table = comp.table()
    instr_by_name = {i.name: i for i in comp.instrs}
    # convert-only fusions feeding dots are fused away on TPU (bf16 operands
    # go straight to the MXU): absorb them into the dot's operand read at the
    # pre-convert dtype and don't count the fusion itself.
    absorbed: set = set()
    for instr in comp.instrs:
        if instr.opcode != "dot":
            continue
        for o in instr.operands():
            src = instr_by_name.get(o)
            if src is not None and src.opcode == "fusion" and "convert" in src.name:
                absorbed.add(o)
    for instr in comp.instrs:
        op = instr.opcode
        if op == "dot":
            total.flops += _dot_flops(instr, table)
            out_b = _shape_bytes(instr.type_str)
            in_b = 0
            for o in instr.operands():
                b = _shape_bytes(table.get(o, ""))
                if o in absorbed:
                    src = instr_by_name[o]
                    m = re.search(r"calls=%([\w.\-]+)", src.rest)
                    fc = comps.get(m.group(1)) if m else None
                    if fc is not None:
                        small = [
                            _DTYPE_BYTES.get(_SHAPE_RE.search(p.type_str).group(1), 4)
                            for p in fc.instrs
                            if p.opcode == "parameter" and _SHAPE_RE.search(p.type_str)
                        ]
                        out_dt = _SHAPE_RE.search(src.type_str)
                        ob = _DTYPE_BYTES.get(out_dt.group(1), 4) if out_dt else 4
                        if small and min(small) < ob:
                            b = b * min(small) // ob
                in_b += b
            total.mem_bytes += out_b + in_b
        elif op == "convolution":
            # rough: 2 * out * (kernel spatial x in-ch) — none of our archs
            total.flops += 2.0 * _shape_bytes(instr.type_str)
        elif any(op.startswith(c) for c in _COLLECTIVES):
            base = op.replace("-start", "").replace("-done", "")
            if op.endswith("-done"):
                continue  # counted at -start
            nbytes = _shape_bytes(instr.type_str)
            in_bytes = sum(_shape_bytes(table.get(o, "")) for o in instr.operands())
            # XLA:CPU upcasts bf16 dot operands to f32 BEFORE the SPMD
            # all-gathers; a TPU compile gathers bf16.  Detect the
            # convert-producing operand and count logical (pre-convert) bytes.
            f = _convert_factor(instr, comp, comps)
            nbytes *= f
            in_bytes *= f
            g = _group_size(instr.rest, n_devices)
            if base == "all-gather":
                c = nbytes * (g - 1) / max(g, 1)
            elif base == "all-reduce":
                c = 2.0 * nbytes * (g - 1) / max(g, 1)
            elif base == "reduce-scatter":
                c = in_bytes * (g - 1) / max(g, 1)
            elif base == "all-to-all":
                c = nbytes * (g - 1) / max(g, 1)
            else:  # collective-permute
                c = nbytes
            total.coll_bytes += c
            total.coll_by_op[base] = total.coll_by_op.get(base, 0.0) + c
        elif op == "fusion":
            if instr.name in absorbed:
                continue
            m = re.search(r"calls=%([\w.\-]+)", instr.rest)
            fc = comps.get(m.group(1)) if m else None
            if "dynamic-update-slice" in instr.name:
                # in-place stash update: traffic = the updated slice (twice:
                # read-modify-write), never the whole aliased buffer
                op_bytes = sorted(
                    _shape_bytes(table.get(o, "")) for o in instr.operands()
                )
                total.mem_bytes += 2 * sum(op_bytes[:-1]) if op_bytes else 0
            elif fc is not None:
                in_b = _fusion_read_bytes(fc, instr, table)
                total.mem_bytes += _shape_bytes(instr.type_str) + in_b
            else:
                in_b = sum(_shape_bytes(table.get(o, "")) for o in instr.operands())
                total.mem_bytes += _shape_bytes(instr.type_str) + in_b
            if fc is not None:
                total += _comp_cost(fc, comps, memo, n_devices)
        elif op == "dynamic-update-slice":
            op_bytes = sorted(
                _shape_bytes(table.get(o, "")) for o in instr.operands()
            )
            total.mem_bytes += 2 * sum(op_bytes[:-1]) if op_bytes else 0
        elif op in ("custom-call", "copy", "scatter",
                    "gather", "dynamic-slice", "sort"):
            total.mem_bytes += _shape_bytes(instr.type_str)
        elif op == "while":
            m = re.search(r"condition=%([\w.\-]+), body=%([\w.\-]+)", instr.rest)
            if m:
                cond_name, body_name = m.group(1), m.group(2)
                trips = _trip_count(comps[cond_name]) if cond_name in comps else 1
                body = comps.get(body_name)
                if body is not None:
                    total += _comp_cost(body, comps, memo, n_devices).scaled(trips)
        elif op in ("call", "conditional"):
            for m in re.finditer(
                r"(?:to_apply|branch_computations=\{?|true_computation|false_computation)=?%([\w.\-]+)",
                instr.rest,
            ):
                if m.group(1) in comps:
                    total += _comp_cost(comps[m.group(1)], comps, memo, n_devices)
    memo[comp.name] = total
    return total


def analyze_hlo(text: str, *, n_devices: int, entry: Optional[str] = None) -> Cost:
    comps = parse_hlo(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: Dict[str, Cost] = {}
    return _comp_cost(comps[entry], comps, memo, n_devices)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(cost: Cost) -> dict:
    t_c = cost.flops / HW["flops_bf16"]
    t_m = cost.mem_bytes / HW["hbm_bw"]
    t_x = cost.coll_bytes / HW["ici_bw"]
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "step_lower_bound_s": bound,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
        "flops": cost.flops,
        "mem_bytes": cost.mem_bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_by_op": cost.coll_by_op,
    }


def model_flops(cfg, shape, *, n_devices: int) -> float:
    """Per-device MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode
    (N = active params)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_devices
    return 2.0 * n_active * shape.global_batch / n_devices


def main():  # pragma: no cover - CLI
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("summary", nargs="?",
                    default=str(Path(__file__).resolve().parents[1]
                                / "dryrun_results" / "summary.json"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import SHAPES, get_config

    summary = json.loads(Path(args.summary).read_text())
    rows = {}
    for cid, rec in summary.items():
        if not rec.get("ok") or "hlo_path" not in rec:
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        cost = analyze_hlo(Path(rec["hlo_path"]).read_text(),
                           n_devices=rec["devices"])
        terms = roofline_terms(cost)
        mf = model_flops(cfg, shape, n_devices=rec["devices"])
        terms["model_flops"] = mf
        terms["useful_fraction"] = mf / cost.flops if cost.flops else 0.0
        rows[cid] = terms
        print(
            f"{cid:45s} comp={terms['compute_s']*1e3:9.2f}ms "
            f"mem={terms['memory_s']*1e3:9.2f}ms coll={terms['collective_s']*1e3:9.2f}ms "
            f"dom={terms['dominant']:10s} useful={terms['useful_fraction']:.2f}"
        )
    if args.out:
        Path(args.out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
