from .fault import FailureInjector, Heartbeat, ResilientTrainer, StragglerWatchdog

__all__ = ["FailureInjector", "Heartbeat", "ResilientTrainer", "StragglerWatchdog"]
