"""Fault tolerance / elasticity / straggler mitigation for the train loop.

What a 1000+-node deployment needs, scaled to the driver abstractions this
repo can exercise without real hardware (all of it is tested against the
in-process trainer in examples/train_lm_on_walks.py and tests/):

* **Checkpoint/restart** — `ResilientTrainer.run` owns the step loop; every
  ``ckpt_every`` steps it snapshots (params, opt_state, data cursor, rng)
  via the async CheckpointManager.  `resume()` restores the newest
  *committed* checkpoint — including onto a different mesh shape (elastic
  re-mesh: restore re-device_puts against the new NamedShardings).
* **Straggler detection** — per-step wall times feed an EMA watchdog; a
  step slower than ``straggler_factor`` x EMA is logged and counted.  On
  real fleets the same signal triggers hot-spare swap; here it feeds
  metrics and the test asserts the detector fires on an injected delay.
* **Failure injection** — `FailureInjector` raises at a scheduled step so
  tests can prove end-to-end crash -> restart -> bitwise-identical resume.
* **Heartbeat** — a background thread stamps a file every interval; an
  external supervisor (launch script) can detect a hung step loop.
"""

from __future__ import annotations

import dataclasses
import time
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint

__all__ = ["FailureInjector", "Heartbeat", "StragglerWatchdog", "ResilientTrainer"]


class FailureInjector:
    """Deterministically crash at the given steps (tests / chaos drills)."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


class Heartbeat:
    def __init__(self, path: str | Path, interval_s: float = 5.0):
        self.path = Path(path)
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        def beat():
            while not self._stop.wait(self.interval):
                self.path.write_text(str(time.time()))

        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(str(time.time()))
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1)

    def age(self) -> float:
        try:
            return time.time() - float(self.path.read_text())
        except FileNotFoundError:
            return float("inf")


class StragglerWatchdog:
    """EMA step-time watchdog: flags steps slower than factor x EMA."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2, warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.stragglers: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = self.n > self.warmup and dt > self.factor * self.ema
        if is_straggler:
            self.stragglers.append((step, dt, self.ema))
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class ResilientTrainer:
    """Owns the step loop: data cursor, checkpoints, watchdog, restart."""

    train_step: Callable  # (params, opt_state, batch) -> (params, opt_state, metrics)
    ckpt_dir: str | Path
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    injector: Optional[FailureInjector] = None
    heartbeat_path: Optional[str | Path] = None

    def run(
        self,
        params,
        opt_state,
        batches: Iterator[dict],
        *,
        num_steps: int,
        start_step: int = 0,
        on_metrics: Optional[Callable[[int, Dict[str, Any]], None]] = None,
    ):
        mgr = CheckpointManager(self.ckpt_dir, keep=self.keep)
        watchdog = StragglerWatchdog(self.straggler_factor)
        hb = Heartbeat(self.heartbeat_path) if self.heartbeat_path else None
        if hb:
            hb.start()
        step = start_step
        last_cursor = None
        try:
            for batch in batches:
                if step >= num_steps:
                    break
                cursor = batch.pop("cursor", None)
                batch.pop("epoch", None)
                if self.injector:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch
                )
                # block so the watchdog sees real step time
                metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                straggler = watchdog.observe(step, dt)
                metrics.update(step_time=dt, straggler=straggler)
                if on_metrics:
                    on_metrics(step, metrics)
                step += 1
                last_cursor = cursor
                if step % self.ckpt_every == 0:
                    mgr.save_async(
                        step,
                        {"params": params, "opt_state": opt_state},
                        extra={"cursor": cursor, "step": step},
                    )
            mgr.save_async(
                step,
                {"params": params, "opt_state": opt_state},
                extra={"cursor": last_cursor, "step": step},
            )
            mgr.wait()
        finally:
            if hb:
                hb.stop()
        return params, opt_state, {"stragglers": watchdog.stragglers, "step": step}

    def resume(self, params_like, opt_like, *, shardings=None):
        """Restore the latest committed state (possibly onto a new mesh).
        Returns (params, opt_state, start_step, cursor) or None if fresh."""
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None
        tree, extra = restore_checkpoint(
            self.ckpt_dir,
            {"params": params_like, "opt_state": opt_like},
            shardings=shardings,
        )
        return tree["params"], tree["opt_state"], extra["step"], extra.get("cursor")
