"""Optional-import shim for `hypothesis` property-based tests.

The test suite uses a small subset of hypothesis (``@given`` with
``integers`` / ``sampled_from`` / ``lists`` strategies and ``@settings``).
This module re-exports the real library when it is installed; otherwise it
falls back to a deterministic, seeded sampler that runs each property over
``max_examples`` randomly drawn (but reproducible) examples, so the tier-1
suite collects and passes offline.

Usage in tests::

    from repro.testing import given, settings, st
"""

from __future__ import annotations

__all__ = ["given", "settings", "st", "strategies", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies as st

    strategies = st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng):
            return self._draw(rng)

    class _StrategyNamespace:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                k = int(rng.integers(min_size, max_size + 1))
                return [elem.example_from(rng) for _ in range(k)]

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

    st = strategies = _StrategyNamespace()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Record ``max_examples``; other hypothesis knobs are meaningless here."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        """Run the property over seeded examples (seed = hash of test name)."""

        def deco(fn):
            max_examples = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)

            # deliberately NOT functools.wraps: the wrapper must expose a
            # bare signature so pytest does not mistake strategy parameters
            # for fixtures
            def wrapper(*args, **kwargs):
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(max_examples):
                    drawn = {
                        name: s.example_from(rng)
                        for name, s in strategy_kwargs.items()
                    }
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__qualname__ = fn.__qualname__
            return wrapper

        return deco
