from .corpus import BOS_OFFSET, WalkCorpus, skipgram_pairs

__all__ = ["BOS_OFFSET", "WalkCorpus", "skipgram_pairs"]
