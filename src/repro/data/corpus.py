"""Walk corpus -> LM training batches.

This is the integration point between the paper's system and the assigned
LM architectures (DESIGN.md §4): DeepWalk/Node2vec walks ARE token
sequences over the vertex vocabulary.  The pipeline packs walk sequences
into fixed-length LM examples (BOS-separated, label-shifted) and also emits
skip-gram pairs for classical embedding training.

Determinism & fault tolerance: the corpus is addressed by a monotone cursor
(walk index); the cursor is part of the checkpoint manifest, so a restarted
job resumes mid-epoch on the exact same batch order (runtime/fault.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["WalkCorpus", "skipgram_pairs"]

BOS_OFFSET = 1  # token 0 = BOS/separator; vertex v -> token v + 1


@dataclasses.dataclass
class WalkCorpus:
    """walks: [N, L+1] int32 with -1 padding after early termination."""

    walks: np.ndarray
    vocab_size: int  # num_vertices + BOS_OFFSET

    @classmethod
    def from_walks(cls, walks: np.ndarray, num_vertices: int) -> "WalkCorpus":
        return cls(np.asarray(walks, np.int32), num_vertices + BOS_OFFSET)

    def __len__(self) -> int:
        return int(self.walks.shape[0])

    def token_stream(self, cursor: int = 0) -> Iterator[np.ndarray]:
        """Yield per-walk token arrays: [BOS, v0+1, v1+1, ...]."""
        n = len(self)
        for i in range(cursor, n):
            w = self.walks[i]
            w = w[w >= 0]
            yield np.concatenate([[0], w.astype(np.int64) + BOS_OFFSET])

    def batches(
        self,
        batch_size: int,
        seq_len: int,
        *,
        cursor: int = 0,
        epochs: Optional[int] = None,
        seed: int = 0,
    ) -> Iterator[dict]:
        """Packed LM batches: {tokens [B,S], labels [B,S], cursor}.

        Walks are concatenated (BOS-separated) then chunked; labels are the
        next-token shift.  Each batch starts fresh at its walk cursor and
        the partial-walk remainder is DISCARDED at the batch boundary —
        batches are therefore a pure function of (seed, cursor), which is
        what makes crash->restart resume bitwise exact (runtime/fault.py);
        the cost is < 1 walk of tokens per batch.
        """
        need = batch_size * (seq_len + 1)
        epoch = 0
        i = cursor
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self))
        while epochs is None or epoch < epochs:
            buf = np.zeros(0, np.int64)
            while i < len(self) and buf.shape[0] < need:
                w = self.walks[order[i]]
                w = w[w >= 0].astype(np.int64) + BOS_OFFSET
                buf = np.concatenate([buf, [0], w])
                i += 1
            if buf.shape[0] >= need:
                chunk = buf[:need].reshape(batch_size, seq_len + 1)
                yield {
                    "tokens": chunk[:, :-1].astype(np.int32),
                    "labels": chunk[:, 1:].astype(np.int32),
                    "cursor": i,
                    "epoch": epoch,
                }
            if i >= len(self):
                i = 0
                order = rng.permutation(len(self))
                epoch += 1


def skipgram_pairs(
    walks: np.ndarray, window: int = 5, *, max_pairs: Optional[int] = None,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(center, context) pairs for word2vec-style embedding training —
    exactly how Node2vec consumes its walks."""
    rng = np.random.default_rng(seed)
    centers, contexts = [], []
    for row in walks:
        row = row[row >= 0]
        L = row.shape[0]
        for i in range(L):
            w = rng.integers(1, window + 1)
            lo, hi = max(0, i - w), min(L, i + w + 1)
            for j in range(lo, hi):
                if j != i:
                    centers.append(row[i])
                    contexts.append(row[j])
        if max_pairs and len(centers) >= max_pairs:
            break
    c = np.asarray(centers[:max_pairs], np.int32)
    x = np.asarray(contexts[:max_pairs], np.int32)
    return c, x
