"""Block store — resident-block cache + background prefetch over a graph
backend (the in-RAM :class:`~repro.core.graph.BlockedGraph` or the
file-backed :class:`~repro.io.blockfile.DiskBlockedGraph`).

The triangular schedule (§4.2) makes the *next* ancillary block known before
the current bucket finishes executing, so its materialisation can overlap the
jitted ``advance_pair`` call.  :class:`BlockStore` wraps the backend's
``materialize_block`` with

* an LRU cache of materialised :class:`~repro.core.graph.ResidentBlock`\\ s
  (bounded, unlike the unbounded page-cache model inside ``BlockedGraph``);
* a one-worker background prefetcher: :meth:`prefetch` starts materialising a
  block on a thread; a later :meth:`get` joins the in-flight future instead
  of materialising on the critical path.

Accounting is unchanged from the seed engines: every :meth:`get` with
``charge=True`` charges exactly one ``block_load`` — prefetching never
charges, so a prefetched block is served without a second charge and the
deterministic I/O counts (the paper's tables) are identical with prefetch on
or off.  Prefetch wins show up as real wall-clock overlap, and are counted
in :attr:`prefetch_hits`.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Optional

from repro.core.graph import ResidentBlock
from repro.core.stats import IOStats

__all__ = ["BlockStore"]


class BlockStore:
    """Metered, cached, prefetching access to a graph backend's blocks.

    ``bg`` is anything exposing ``materialize_block(b) -> ResidentBlock``
    plus the blocked-graph metadata surface — for the file-backed
    :class:`~repro.io.blockfile.DiskBlockedGraph` the LRU + prefetch thread
    here is what hides real file reads from the critical path.
    """

    def __init__(
        self,
        bg,
        stats: IOStats,
        *,
        capacity: int = 4,
        enable_prefetch: bool = True,
    ):
        if capacity < 2:
            raise ValueError("BlockStore needs capacity >= 2 (a resident pair)")
        self.bg = bg
        self.stats = stats
        self.capacity = capacity
        self.enable_prefetch = enable_prefetch
        self._cache: "OrderedDict[int, ResidentBlock]" = OrderedDict()
        self._futures: Dict[int, Future] = {}
        self._lock = threading.Lock()
        self._mat_lock = threading.Lock()  # serialises materialize_block
        self._executor: Optional[ThreadPoolExecutor] = None
        self.prefetch_issued = 0
        self.prefetch_hits = 0
        self.cache_hits = 0
        self.demand_loads = 0
        #: wall time get() spent materialising on the calling thread — the
        #: quantity prefetch removes from the critical path
        self.sync_materialize_time = 0.0
        #: wall time get() spent waiting on a not-yet-finished prefetch
        self.prefetch_wait_time = 0.0

    # -- internals ------------------------------------------------------------
    def _materialize(self, b: int) -> ResidentBlock:
        with self._mat_lock:
            return self.bg.materialize_block(b)

    def _insert(self, b: int, blk: ResidentBlock) -> None:
        with self._lock:
            self._cache[b] = blk
            self._cache.move_to_end(b)
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)

    # -- the engine-facing API -------------------------------------------------
    def prefetch(self, b: int) -> None:
        """Start materialising block ``b`` in the background (no charge)."""
        if not self.enable_prefetch:
            return
        b = int(b)
        with self._lock:
            if b in self._cache or b in self._futures:
                return
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="blockstore-prefetch"
                )
            self._futures[b] = self._executor.submit(self._materialize, b)
            self.prefetch_issued += 1

    def get(self, b: int, *, sequential: bool = True, charge: bool = True) -> ResidentBlock:
        """Resident block ``b``; charges one ``block_load`` unless ``charge=False``.

        The charge models the paper's deterministic accounting (the page
        cache is bypassed), so cache/prefetch hits still pay the modelled
        I/O — they only skip the host-side materialisation latency.
        """
        b = int(b)
        with self._lock:
            fut = self._futures.pop(b, None)
            blk = self._cache.get(b)
        if fut is not None:
            t0 = time.perf_counter()
            blk = fut.result()
            self.prefetch_wait_time += time.perf_counter() - t0
            self.prefetch_hits += 1
        elif blk is not None:
            self.cache_hits += 1
        else:
            t0 = time.perf_counter()
            blk = self._materialize(b)
            self.sync_materialize_time += time.perf_counter() - t0
            self.demand_loads += 1
        self._insert(b, blk)
        if charge:
            self.stats.block_load(b, blk.nbytes_full(), sequential=sequential)
        return blk

    def counters(self) -> dict:
        return {
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
            "cache_hits": self.cache_hits,
            "demand_loads": self.demand_loads,
            "sync_materialize_time": self.sync_materialize_time,
            "prefetch_wait_time": self.prefetch_wait_time,
        }

    def close(self) -> None:
        with self._lock:
            futures, self._futures = self._futures, {}
            executor, self._executor = self._executor, None
        for fut in futures.values():
            fut.cancel()
        if executor is not None:
            executor.shutdown(wait=True)
