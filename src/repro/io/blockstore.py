"""Block store — resident-view cache + background prefetch over a graph
backend (the in-RAM :class:`~repro.core.graph.BlockedGraph` or the
file-backed :class:`~repro.io.blockfile.DiskBlockedGraph`).

The store's currency is the :class:`~repro.core.graph.BlockView`: engines
ask for *full* views (the whole block) or *partial* views (a compacted CSR
over exactly the activated vertices of a bucket).  The triangular schedule
(§4.2) makes the *next* ancillary bucket known before the current one
finishes executing, so either kind of load can overlap the jitted
``advance_pair`` call:

* an LRU cache of materialised :class:`~repro.core.graph.ResidentBlock`\\ s
  (bounded, unlike the unbounded page-cache model inside ``BlockedGraph``);
* one pending partial view per block: a bucket only ever *gains* walks
  between the prefetch and its execution (Alg. 2 extension), so a
  prefetched partial view is a subset of the set eventually requested —
  :meth:`partial_view` serves it as a base and gathers only the missing
  rows, and discards it if it is not a subset (a stale prediction).  The
  served view always holds *exactly* the requested activated set, so
  prefetching can never change what executes;
* a one-worker background prefetcher: :meth:`prefetch` /
  :meth:`prefetch_partial` start materialising on a thread; a later
  :meth:`get` / :meth:`partial_view` joins the in-flight future instead of
  materialising on the critical path.  This is the seam the async bucket
  pipeline grows from.

Accounting is unchanged from the seed engines: every :meth:`get` with
``charge=True`` charges exactly one ``block_load``; partial views are never
charged here (the engine charges the on-demand transfer deterministically).
Prefetching never charges, so the deterministic I/O counts (the paper's
tables) are identical with prefetch on or off.  Prefetch wins show up as
real wall-clock overlap, counted in :attr:`prefetch_hits` /
:attr:`partial_prefetch_hits`.

**Hot-set policy** (serving layer; ROADMAP "walk-query serving").  The
query-serving front end (:mod:`repro.serve`) observes which blocks its
query sources land in and :meth:`pin`\\ s the high-traffic ones.  A pinned
block is materialised (and charged) once, then held *resident outside the
LRU* — eviction only ever governs the cold tail — and every later charged
:meth:`get` is served from the pinned copy **without** a ``block_load``
charge: the block genuinely never re-crosses the slow/fast boundary, which
is the whole point of serving hot traffic from memory (§4.2's bucket
economics turned into a latency story; ThunderRW's in-memory regime on the
hot set, graceful degradation to disk on the cold tail).  The skipped
charges are metered as deterministic gauges (``IOStats.pinned_block_hits``
/ ``pinned_bytes_saved``; ``hot_pinned_blocks`` tracks the policy state) —
pinned membership and the access sequence are program-order pure, so the
savings are exactly reproducible.  Batch engines pin nothing, so their
accounting (the paper's tables) is untouched.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Optional

import numpy as np

from repro.core.graph import BlockView, ResidentBlock
from repro.core.stats import IOStats
from repro.io.ioplan import model_ondemand_io

__all__ = ["BlockStore"]


class BlockStore:
    """Metered, cached, prefetching access to a graph backend's block views.

    ``bg`` is anything exposing ``materialize_block(b) -> ResidentBlock``
    and ``partial_view(b, vertices) -> BlockView`` plus the blocked-graph
    metadata surface — for the file-backed
    :class:`~repro.io.blockfile.DiskBlockedGraph` the LRU + prefetch thread
    here is what hides real file reads from the critical path.
    """

    def __init__(
        self,
        bg,
        stats: IOStats,
        *,
        capacity: int = 4,
        enable_prefetch: bool = True,
    ):
        if capacity < 2:
            raise ValueError("BlockStore needs capacity >= 2 (a resident pair)")
        self.bg = bg
        self.stats = stats
        self.capacity = capacity
        self.enable_prefetch = enable_prefetch
        self._cache: "OrderedDict[int, ResidentBlock]" = OrderedDict()
        # hot set: block id -> resident copy (None until first touch);
        # pinned blocks live outside the LRU and are exempt from eviction
        self._pinned: "OrderedDict[int, Optional[ResidentBlock]]" = OrderedDict()
        self._futures: Dict[int, Future] = {}
        # one pending partial-view build per block (consumed by partial_view)
        self._pfutures: Dict[int, Future] = {}
        self._lock = threading.Lock()
        self._mat_lock = threading.Lock()  # serialises backend reads
        self._executor: Optional[ThreadPoolExecutor] = None
        self.prefetch_issued = 0
        self.prefetch_hits = 0
        self.cache_hits = 0
        self.demand_loads = 0
        self.partial_prefetch_issued = 0
        self.partial_prefetch_hits = 0
        self.partial_builds = 0
        self.pinned_hits = 0
        #: wall time get() spent materialising on the calling thread — the
        #: quantity prefetch removes from the critical path
        self.sync_materialize_time = 0.0
        #: wall time get() spent waiting on a not-yet-finished prefetch
        self.prefetch_wait_time = 0.0

    # -- internals ------------------------------------------------------------
    def _materialize(self, b: int) -> ResidentBlock:
        with self._mat_lock:
            return self.bg.materialize_block(b)

    def _build_partial(self, b: int, vertices: np.ndarray) -> BlockView:
        with self._mat_lock:
            return self.bg.partial_view(b, vertices)

    def _note_ondemand_plan(self, vertices: np.ndarray) -> None:
        """Meter the read planner's gauges for an on-demand request over
        ``vertices`` — the *modelled* syscall/range/waste counts from
        :func:`repro.io.ioplan.model_ondemand_io`, charged in program order
        on the engine thread.  Like every deterministic charge, the gauge
        covers the full requested set whether or not a prefetched base
        served part of it, so the values are identical across prefetch /
        async / backend configurations (and equal the real
        ``DiskBlockedGraph`` counters when prefetch is off)."""
        gap = int(getattr(self.bg, "io_coalesce_gap", 0))
        syscalls, ranges, waste = model_ondemand_io(self.bg, vertices, gap)
        if syscalls or ranges or waste:
            self.stats.note_ondemand_plan(syscalls, ranges, waste)

    def _insert(self, b: int, blk: ResidentBlock) -> None:
        with self._lock:
            self._cache[b] = blk
            self._cache.move_to_end(b)
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)

    def _submit(self, fn, *args) -> Future:
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1,
                thread_name_prefix="blockstore-prefetch",
            )
        return self._executor.submit(fn, *args)

    # -- the engine-facing API -------------------------------------------------
    def schedule(self, ops) -> None:
        """Schedule a batch of prefetches from a pipeline plan.

        ``ops`` is an iterable of ``("full", b)`` / ``("partial", b,
        vertices)`` tuples — the :class:`repro.engines.pipeline
        .BucketPipeline` derives them from the
        :class:`~repro.core.scheduler.TimeSlotPlan` (next slot's current
        block, next bucket's ancillary view) instead of issuing one-off
        calls.  Same-slot partial requests against one block are batched:
        their vertex sets union into a single prefetched build, so the read
        planner sees one plan per block instead of one per request.  Never
        charges; a no-op when prefetch is disabled.
        """
        partials: Dict[int, list] = {}
        for op in ops:
            if op[0] == "full":
                self.prefetch(op[1])
            elif op[0] == "partial":
                partials.setdefault(int(op[1]), []).append(
                    np.asarray(op[2], dtype=np.int64)
                )
            else:
                raise ValueError(f"unknown prefetch op {op[0]!r}; have full, partial")
        for b, sets in partials.items():
            vs = sets[0] if len(sets) == 1 else np.unique(np.concatenate(sets))
            self.prefetch_partial(b, vs)

    # -- hot-set policy (serving layer) ----------------------------------------
    def pin(self, blocks) -> None:
        """Pin ``blocks`` into the hot set.  A pinned block is charged one
        ``block_load`` on first touch, then held resident outside the LRU;
        later charged :meth:`get`\\ s skip the charge and meter the saving
        (``IOStats.pinned_block_hits`` / ``pinned_bytes_saved``).  Already
        pinned ids (and their resident copies) are kept."""
        with self._lock:
            for b in blocks:
                b = int(b)
                if b not in self._pinned:
                    # promote an LRU-resident copy instead of re-reading it
                    self._pinned[b] = self._cache.pop(b, None)
            self.stats.note_hot_set(len(self._pinned))

    def unpin(self, blocks) -> None:
        """Release ``blocks`` from the hot set; they rejoin the cold tail
        (their resident copies re-enter the LRU and compete for capacity
        again, and every later charged :meth:`get` pays ``block_load``)."""
        with self._lock:
            for b in blocks:
                blk = self._pinned.pop(int(b), None)
                if blk is not None:
                    self._cache[int(b)] = blk
                    self._cache.move_to_end(int(b))
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)
            self.stats.note_hot_set(len(self._pinned))

    def set_pinned(self, blocks) -> None:
        """Replace the hot set: pin the new ids, release the dropped ones.
        The serving layer calls this at every admission batch with the
        policy's current top-traffic blocks."""
        want = {int(b) for b in blocks}
        self.unpin([b for b in list(self._pinned) if b not in want])
        self.pin(sorted(want))

    def pinned(self) -> frozenset:
        """The hot set's block ids."""
        with self._lock:
            return frozenset(self._pinned)

    def prefetch(self, b: int) -> None:
        """Start materialising block ``b`` in the background (no charge)."""
        if not self.enable_prefetch:
            return
        b = int(b)
        with self._lock:
            if b in self._cache or b in self._futures:
                return
            if self._pinned.get(b) is not None:
                return  # pinned resident: nothing to build
            self._futures[b] = self._submit(self._materialize, b)
            self.prefetch_issued += 1

    def prefetch_partial(self, b: int, vertices: np.ndarray) -> None:
        """Start building the partial view of block ``b`` over ``vertices``
        in the background (no charge).  A later :meth:`partial_view` call
        uses it as a base when its set is a subset of the request (buckets
        only grow between prefetch and execution) and gathers the missing
        rows; otherwise it is discarded."""
        if not self.enable_prefetch:
            return
        b = int(b)
        with self._lock:
            # always replace the pending prediction: an unconsumed one is
            # stale (its bucket chose a full load after all), and keeping an
            # in-flight one only when it is still running would make which
            # prediction partial_view sees — and the overlapped_load_bytes
            # it counts — depend on prefetch-thread timing.  The superseded
            # build finishes in the background and is dropped.
            self._pfutures[b] = self._submit(self._build_partial, b, np.asarray(vertices))
            self.partial_prefetch_issued += 1

    def get(self, b: int, *, sequential: bool = True, charge: bool = True) -> ResidentBlock:
        """Resident block ``b``; charges one ``block_load`` unless ``charge=False``.

        The charge models the paper's deterministic accounting (the page
        cache is bypassed), so cache/prefetch hits still pay the modelled
        I/O — they only skip the host-side materialisation latency.  The
        one exception is the **hot set**: a :meth:`pin`\\ ned block is
        charged on first touch only; later charged gets are served from the
        pinned copy with the avoided charge metered as a deterministic
        saving (the serving layer's whole point).
        """
        b = int(b)
        with self._lock:
            pinned = b in self._pinned
            blk = self._pinned.get(b) if pinned else self._cache.get(b)
            fut = self._futures.pop(b, None)
        if pinned:
            if blk is not None:
                self.pinned_hits += 1
                if charge:
                    self.stats.note_pinned_hit(blk.nbytes_full())
                return blk
            # first touch: materialise (joining any in-flight prefetch),
            # pay the normal block_load charge, and keep the copy pinned
            if fut is not None:
                t0 = time.perf_counter()
                blk = fut.result()
                self.prefetch_wait_time += time.perf_counter() - t0
                self.prefetch_hits += 1
                self.stats.note_overlapped(blk.nbytes_full())
            else:
                t0 = time.perf_counter()
                blk = self._materialize(b)
                self.sync_materialize_time += time.perf_counter() - t0
                self.demand_loads += 1
            with self._lock:
                if b in self._pinned:
                    self._pinned[b] = blk
                else:  # unpinned while materialising: fall back to the LRU
                    self._insert(b, blk)
            if charge:
                self.stats.block_load(b, blk.nbytes_full(), sequential=sequential)
            return blk
        if fut is not None:
            t0 = time.perf_counter()
            blk = fut.result()
            self.prefetch_wait_time += time.perf_counter() - t0
            self.prefetch_hits += 1
            # the materialisation ran off the critical path — measure the win
            self.stats.note_overlapped(blk.nbytes_full())
        elif blk is not None:
            self.cache_hits += 1
        else:
            t0 = time.perf_counter()
            blk = self._materialize(b)
            self.sync_materialize_time += time.perf_counter() - t0
            self.demand_loads += 1
        self._insert(b, blk)
        if charge:
            self.stats.block_load(b, blk.nbytes_full(), sequential=sequential)
        return blk

    def get_view(self, b: int, *, sequential: bool = True, charge: bool = True) -> BlockView:
        """Full :class:`BlockView` of block ``b`` (same charging as
        :meth:`get`)."""
        return BlockView.from_resident(self.get(b, sequential=sequential, charge=charge))

    def partial_view(self, b: int, vertices: np.ndarray) -> BlockView:
        """Activated view of block ``b`` over exactly the unique
        ``vertices``.

        Never charges — the *engine* charges the on-demand transfer
        (``IOStats.ondemand_load``) deterministically, whether or not the
        view was prefetched.  A pending prefetched view whose vertex set is
        a subset of the request becomes the base; only the missing rows are
        gathered.  The returned view holds *exactly* the requested set
        either way, so prefetching never changes what executes.
        """
        b = int(b)
        vs = np.unique(np.asarray(vertices, dtype=np.int64))
        # gauge the plan over the full requested set (prefetch-invariant)
        self._note_ondemand_plan(vs)
        base = None
        with self._lock:
            fut = self._pfutures.pop(b, None)
        if fut is not None:
            t0 = time.perf_counter()
            base = fut.result()
            self.prefetch_wait_time += time.perf_counter() - t0
        if base is not None:
            in_req = np.isin(base.vids, vs)
            if in_req.all():
                self.partial_prefetch_hits += 1
                self.stats.note_overlapped(self.bg.activated_load_bytes(base.vids))
                missing = vs[~base.has_vertices(vs)]
                if missing.size:
                    base = self._extend(base, missing)
                return base
        t0 = time.perf_counter()
        view = self._build_partial(b, vs)
        self.sync_materialize_time += time.perf_counter() - t0
        self.partial_builds += 1
        return view

    def _extend(self, view: BlockView, vertices: np.ndarray) -> BlockView:
        extra = self._build_partial(view.block_id, vertices)
        return view.extended(extra)

    def extend_view(self, view: BlockView, vertices: np.ndarray) -> BlockView:
        """Mid-advance extension gather: append the rows of ``vertices`` to
        an activated ``view`` (never charges bytes; the engine accounts the
        gather as on-demand vertex I/O).  Meters the read-planner gauges
        for the gathered set."""
        self._note_ondemand_plan(np.asarray(vertices, dtype=np.int64))
        return self._extend(view, vertices)

    def gather_view(self, vertices: np.ndarray) -> BlockView:
        """Cross-block activated view over arbitrary vertices (never
        charges bytes; the engine accounts the per-vertex fetches).  Meters
        the read-planner gauges for the gathered set."""
        self._note_ondemand_plan(np.asarray(vertices, dtype=np.int64))
        with self._mat_lock:
            return self.bg.gather_view(vertices)

    def counters(self) -> dict:
        return {
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
            "cache_hits": self.cache_hits,
            "demand_loads": self.demand_loads,
            "partial_prefetch_issued": self.partial_prefetch_issued,
            "partial_prefetch_hits": self.partial_prefetch_hits,
            "partial_builds": self.partial_builds,
            "pinned_blocks": len(self._pinned),
            "pinned_hits": self.pinned_hits,
            "sync_materialize_time": self.sync_materialize_time,
            "prefetch_wait_time": self.prefetch_wait_time,
        }

    def close(self) -> None:
        with self._lock:
            futures = list(self._futures.values()) + list(self._pfutures.values())
            self._futures = {}
            self._pfutures = {}
            self._pinned = OrderedDict()
            executor, self._executor = self._executor, None
        for fut in futures:
            fut.cancel()
        if executor is not None:
            executor.shutdown(wait=True)
