"""Gap-aware read planner for the on-demand path (the paper's central claim
— random I/Os turned into sequential I/Os — applied to Fig. 5(b)'s access
pattern).

The per-vertex reference path issues four tiny ``pread``\\ s per activated
vertex: index-entry pair, row segment, alias_j, alias_q.  This module plans
the same transfer as a handful of large ranged reads instead:

1. the 8-byte index-entry pairs of a block's sorted activated vertices are
   fetched in one ranged read over ``[min_v, max_v]`` of the index region
   (or a few gap-split ranges);
2. the resulting row extents — and the parallel alias_j/alias_q extents —
   are merged into coalesced ranges under a waste budget ``gap_bytes``: a
   hole between two extents no larger than the budget is *read through*
   rather than paid for with a seek;
3. the plan executes as one ``pread`` per range and the per-vertex segments
   are sliced out in memory.

The planner is pure byte-extent math over resident metadata (degrees +
block starts), so the same function drives both the real executor
(:class:`repro.io.blockfile.DiskBlockedGraph`) and the *modelled*
deterministic gauges (:func:`model_ondemand_io`, charged through
``IOStats.note_ondemand_plan`` by the :class:`~repro.io.blockstore
.BlockStore` on either graph backend).  Merging and waste are invariant
under a constant offset shift, so planning in block-relative file
coordinates (executor) and in global CSR coordinates (model) yields the
same range count and the same waste — the property the real-vs-charged
counter tests pin.

Accounting stays honest: useful bytes (what ``activated_load_bytes``
charges) never change; the read-through hole bytes are metered separately
as ``coalesce_waste_bytes``.  ``gap_bytes <= 0`` means the planner is off
and the per-vertex reference path runs bit-for-bit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import block_of

__all__ = ["ReadPlan", "plan_reads", "execute_plan", "model_ondemand_io"]


@dataclasses.dataclass(frozen=True)
class ReadPlan:
    """A batch of coalesced ranged reads plus the per-segment slice table.

    ``ranges`` are half-open ``[start, end)`` byte ranges in the caller's
    (region-relative) coordinates; ``seg_range[k]`` names the range holding
    requested extent ``k`` (``-1`` for an empty extent — no read at all),
    and ``seg_start``/``seg_len`` locate the extent so
    :func:`execute_plan` can slice it out of the range's buffer.
    """

    ranges: np.ndarray  # [R, 2] int64, merged half-open byte ranges
    seg_range: np.ndarray  # [K] int64, owning range per extent (-1: empty)
    seg_start: np.ndarray  # [K] int64, extent start (same coordinates)
    seg_len: np.ndarray  # [K] int64, extent length
    useful_bytes: int  # union of the requested extents
    waste_bytes: int  # read-through hole bytes: total - useful

    @property
    def num_ranges(self) -> int:
        return int(self.ranges.shape[0])

    @property
    def total_bytes(self) -> int:
        if self.ranges.shape[0] == 0:
            return 0
        return int((self.ranges[:, 1] - self.ranges[:, 0]).sum())


def plan_reads(starts, ends, gap_bytes: int = 0) -> ReadPlan:
    """Merge sorted byte extents into gap-aware coalesced ranges.

    ``starts``/``ends`` are parallel arrays of half-open extents, sorted by
    start (the natural order of a block's activated vertices).  The merge
    rule: an extent joins the open range when the hole between them is at
    most ``gap_bytes`` (``next_start - range_end <= gap_bytes``) — the hole
    is read through rather than seeked over.  Overlapping or adjacent
    extents always merge with zero waste, so at ``gap_bytes == 0`` the plan
    moves exactly the union of the requested extents (``waste_bytes == 0``).
    Empty extents consume no range (and no read).
    """
    starts = np.asarray(starts, dtype=np.int64).reshape(-1)
    ends = np.asarray(ends, dtype=np.int64).reshape(-1)
    if starts.shape != ends.shape:
        raise ValueError("starts and ends must be parallel arrays")
    if np.any(ends < starts):
        raise ValueError("extents must satisfy end >= start")
    if starts.size > 1 and np.any(np.diff(starts) < 0):
        raise ValueError("extents must be sorted by start")
    gap = max(int(gap_bytes), 0)
    seg_range = np.full(starts.size, -1, np.int64)
    ranges: list[list[int]] = []
    useful = 0
    cover_end: int | None = None  # union high-water mark (extents are sorted)
    cur: list[int] | None = None
    for k in range(starts.size):
        s0, e0 = int(starts[k]), int(ends[k])
        if e0 == s0:
            continue  # empty extent: nothing to read
        if cover_end is None or s0 >= cover_end:
            useful += e0 - s0
            cover_end = e0
        elif e0 > cover_end:
            useful += e0 - cover_end
            cover_end = e0
        if cur is not None and s0 - cur[1] <= gap:
            cur[1] = max(cur[1], e0)
        else:
            cur = [s0, e0]
            ranges.append(cur)
        seg_range[k] = len(ranges) - 1
    ranges_arr = np.asarray(ranges, np.int64).reshape(-1, 2)
    total = int((ranges_arr[:, 1] - ranges_arr[:, 0]).sum()) if ranges else 0
    return ReadPlan(
        ranges=ranges_arr,
        seg_range=seg_range,
        seg_start=starts.copy(),
        seg_len=ends - starts,
        useful_bytes=useful,
        waste_bytes=total - useful,
    )


def execute_plan(plan: ReadPlan, read, base: int = 0) -> list:
    """Execute ``plan``: one ``read(offset, length)`` per coalesced range,
    then slice the per-extent segments out in memory.  ``base`` shifts the
    plan's region-relative coordinates to absolute file offsets.  Returns
    one buffer (memoryview) per requested extent, ``b""`` for empty ones.
    """
    bufs = [read(base + int(s0), int(e0 - s0)) for s0, e0 in plan.ranges]
    out = []
    for k in range(plan.seg_range.size):
        r = int(plan.seg_range[k])
        if r < 0:
            out.append(b"")
            continue
        off = int(plan.seg_start[k] - plan.ranges[r, 0])
        out.append(memoryview(bufs[r])[off : off + int(plan.seg_len[k])])
    return out


def model_ondemand_io(bg, vertices, gap_bytes: int = 0) -> tuple[int, int, int]:
    """``(syscalls, coalesced_ranges, waste_bytes)`` an on-demand gather of
    ``vertices`` costs under the planner — pure metadata math (degrees +
    block starts), identical on the in-RAM and file-backed graph backends.

    With the planner off (``gap_bytes <= 0``) the reference path issues two
    ``pread``\\ s per unique vertex (index pair + row segment), plus two
    more (alias_j + alias_q) on a weighted graph, and no range was ever
    coalesced.  With the planner on, every region's extents merge under the
    waste budget exactly as the executor merges them (same
    :func:`plan_reads` on offset-shifted copies of the same extents), so
    the modelled gauges equal the real counters whenever the real reads
    happen (prefetch off).
    """
    vs = np.unique(np.asarray(vertices, dtype=np.int64))
    if vs.size == 0:
        return 0, 0, 0
    weighted = bool(bg.has_weights)
    if int(gap_bytes) <= 0:
        return (4 if weighted else 2) * int(vs.size), 0, 0
    rs, re = bg.row_extents(vs)
    owners = block_of(bg.block_starts, vs)
    syscalls = waste = 0
    for b in np.unique(owners):
        m = owners == b
        sub = vs[m]
        # index region: the 8-byte entry pair of each vertex (global
        # coordinates — a constant shift of the on-disk local offsets)
        iplan = plan_reads(4 * sub, 4 * sub + 8, gap_bytes)
        rplan = plan_reads(4 * rs[m], 4 * re[m], gap_bytes)
        n_ranges = iplan.num_ranges + rplan.num_ranges
        n_waste = iplan.waste_bytes + rplan.waste_bytes
        if weighted:
            # alias_j/alias_q extents parallel the row extents: the executor
            # reuses the row plan for both regions
            n_ranges += 2 * rplan.num_ranges
            n_waste += 2 * rplan.waste_bytes
        syscalls += n_ranges
        waste += n_waste
    return syscalls, syscalls, waste
