"""Walk pools — the "disk" tier for partially-finished walks (paper §4.3/§6.1).

A :class:`WalkPool` owns one append-only pool per block.  Engines ``push``
walks to the pool of the block they persist with (skewed ``min(B(u), B(v))``
or traditional ``B(cur)`` association — the *engine* decides the key, the
pool only stores) and ``load`` drains a whole pool at the start of that
block's time slot.

Both backends buffer pushes in memory and *spill* once a block's buffer
reaches ``flush_walks`` (the paper's walk-pool write buffer); a ``load``
first seals the buffer, then returns spilled + buffered walks in exact push
order, so the two backends are observationally identical to the engines:

* :class:`MemoryWalkPool` — spills into a host-memory list; the spill/read
  I/O is *modelled* (charged to :class:`~repro.core.stats.IOStats`) but no
  bytes move.  This is the seed engine's behavior, extracted.
* :class:`DiskWalkPool` — spills real 16-byte packed records
  (:func:`repro.core.walk.pack_walks`, §6.1 Fig. 7) to one append-only file
  per block, so ``IOStats.walk_bytes_written`` equals bytes on disk.  Walk
  ids ride in an int64 sidecar file: they are host bookkeeping for corpus
  recording, not part of the paper's record, and are not charged.

Only spilled walks are charged: a walk that never left the write buffer
never crossed the slow/fast boundary.  ``flush_walks=0`` spills every push
(the seed's accounting), ``flush_walks=None`` never spills before a load.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
from collections import deque
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.stats import IOStats
from repro.core.walk import WALK_BYTES, WalkBatch, pack_walks, unpack_walks

__all__ = [
    "WalkPool",
    "MemoryWalkPool",
    "DiskWalkPool",
    "AsyncWalkPool",
    "ShardedWalkPool",
    "make_walk_pool",
    "shard_of_block",
]

_WID_BYTES = 8


def shard_of_block(b: int, num_shards: int) -> int:
    """Deterministic owner shard of block ``b``'s walk pool.

    Round-robin striping (``b % num_shards``): block ids are small
    *contiguous* integers, so striping is the perfect hash for this
    keyspace — every shard owns an equal slice (a multiplicative hash
    collides badly here: 2 blocks over 2 shards can land on one), it is
    independent of ``PYTHONHASHSEED`` and stable across hosts, and when
    ``num_shards == num_blocks`` it degenerates to the identity — one
    shard per rank, the distributed engine's natural placement.  Every key
    of the ``(block, bucket)`` keyspace an engine persists with — the
    skewed ``min(B(u), B(v))`` or traditional ``B(cur)`` association —
    resolves through this one function, so a block's entire op stream
    lands on one shard, in program order.
    """
    return int(b) % max(int(num_shards), 1)


def _first_missing_ancestor(path: str) -> Optional[str]:
    """The topmost path component ``os.makedirs(path)`` would create (the
    root to remove to undo it), or None when ``path`` already exists."""
    path = os.path.abspath(path)
    if os.path.isdir(path):
        return None
    root = path
    parent = os.path.dirname(root)
    while parent and parent != root and not os.path.isdir(parent):
        root, parent = parent, os.path.dirname(parent)
    return root


@runtime_checkable
class WalkPool(Protocol):
    """Per-block walk storage; see the module docstring for the contract."""

    backend: str
    counts: np.ndarray  # [NB] int64 — walks currently stored per block
    min_hop: np.ndarray  # [NB] float64 — min hop per block (inf when empty)

    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None: ...

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]: ...

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]: ...

    def flush(self, b: Optional[int] = None) -> None: ...

    def close(self) -> None: ...


class _PoolBase:
    """Shared buffering, counting and spill-threshold logic."""

    backend = "base"

    def __init__(self, num_blocks: int, stats: IOStats, flush_walks: Optional[int] = 1 << 18):
        self.num_blocks = num_blocks
        self.stats = stats
        self.flush_walks = flush_walks
        self.counts = np.zeros(num_blocks, np.int64)
        self.min_hop = np.full(num_blocks, np.inf)
        self._buf: Dict[int, List[Tuple[WalkBatch, np.ndarray]]] = {
            b: [] for b in range(num_blocks)
        }
        self._buf_counts = np.zeros(num_blocks, np.int64)

    # -- subclass hooks -------------------------------------------------------
    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        raise NotImplementedError

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        raise NotImplementedError

    def _spilled_count(self, b: int) -> int:
        raise NotImplementedError

    # -- the engine-facing API ------------------------------------------------
    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        self._buf[b].append((batch, wid))
        self._buf_counts[b] += len(batch)
        self.counts[b] += len(batch)
        self.min_hop[b] = min(self.min_hop[b], float(batch.hop.min()))
        if self.flush_walks is not None and self._buf_counts[b] >= self.flush_walks:
            self.flush(b)

    def flush(self, b: Optional[int] = None) -> None:
        """Spill buffered walks to the slow tier (charged as walk writes)."""
        blocks = range(self.num_blocks) if b is None else (b,)
        for blk in blocks:
            entries = self._buf[blk]
            if not entries:
                continue
            self._buf[blk] = []
            n = int(self._buf_counts[blk])
            self._buf_counts[blk] = 0
            batch = WalkBatch.concat([e[0] for e in entries])
            wid = np.concatenate([e[1] for e in entries])
            self._spill(blk, batch, wid)
            self.stats.walk_io(n, kind="write")

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        """Drain pool ``b``: spilled walks (charged as a read) + buffer."""
        n_spilled = self._spilled_count(b)
        spilled_batch, spilled_wid = self._read_spilled(b, consume=True)
        if n_spilled:
            self.stats.walk_io(n_spilled, kind="read")
        entries = self._buf[b]
        self._buf[b] = []
        self._buf_counts[b] = 0
        self.counts[b] = 0
        self.min_hop[b] = np.inf
        batch = WalkBatch.concat([spilled_batch] + [e[0] for e in entries])
        wid = np.concatenate([spilled_wid] + [e[1] for e in entries])
        return batch, wid

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        """Inspect pool ``b`` without consuming or charging (tests/debug)."""
        spilled_batch, spilled_wid = self._read_spilled(b, consume=False)
        entries = self._buf[b]
        batch = WalkBatch.concat([spilled_batch] + [e[0] for e in entries])
        wid = np.concatenate([spilled_wid] + [e[1] for e in entries])
        return batch, wid

    def close(self) -> None:
        pass


class MemoryWalkPool(_PoolBase):
    """Host-memory pools; spill I/O is modelled, not performed."""

    backend = "memory"

    def __init__(self, num_blocks: int, stats: IOStats, flush_walks: Optional[int] = 1 << 18):
        super().__init__(num_blocks, stats, flush_walks)
        self._spilled: Dict[int, List[Tuple[WalkBatch, np.ndarray]]] = {
            b: [] for b in range(num_blocks)
        }
        self._spilled_counts = np.zeros(num_blocks, np.int64)

    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        self._spilled[b].append((batch, wid))
        self._spilled_counts[b] += len(batch)

    def _spilled_count(self, b: int) -> int:
        return int(self._spilled_counts[b])

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        entries = self._spilled[b]
        if consume:
            self._spilled[b] = []
            self._spilled_counts[b] = 0
        if not entries:
            return WalkBatch.empty(), np.zeros(0, np.int64)
        return (
            WalkBatch.concat([e[0] for e in entries]),
            np.concatenate([e[1] for e in entries]),
        )


class DiskWalkPool(_PoolBase):
    """Real per-block append-only files of 16-byte packed walk records."""

    backend = "disk"

    def __init__(
        self,
        num_blocks: int,
        stats: IOStats,
        block_starts: np.ndarray,
        flush_walks: Optional[int] = 1 << 18,
        directory: Optional[str] = None,
    ):
        super().__init__(num_blocks, stats, flush_walks)
        self.block_starts = np.asarray(block_starts, dtype=np.int64)
        self._tmpdir: Optional[tempfile.TemporaryDirectory] = None
        if directory is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="grasorw_pool_")
            directory = self._tmpdir.name
        # directories this pool creates (the whole makedirs chain) are
        # removed wholesale on close; in a pre-existing (user-owned)
        # directory only the spill files are
        self._created_root = _first_missing_ancestor(directory)
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self._spilled_counts = np.zeros(num_blocks, np.int64)
        self.bytes_written = 0

    def record_path(self, b: int) -> str:
        return os.path.join(self.directory, f"pool_{b:05d}.walks")

    def _wid_path(self, b: int) -> str:
        return os.path.join(self.directory, f"pool_{b:05d}.wid")

    def on_disk_bytes(self) -> int:
        """Current total size of all record files (16 bytes per stored walk)."""
        return sum(
            os.path.getsize(p)
            for b in range(self.num_blocks)
            if os.path.exists(p := self.record_path(b))
        )

    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        packed = pack_walks(batch, self.block_starts)
        with open(self.record_path(b), "ab") as f:
            f.write(packed.tobytes())
        with open(self._wid_path(b), "ab") as f:
            f.write(np.asarray(wid, dtype=np.int64).tobytes())
        self._spilled_counts[b] += len(batch)
        self.bytes_written += len(batch) * WALK_BYTES

    def _spilled_count(self, b: int) -> int:
        return int(self._spilled_counts[b])

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        n = int(self._spilled_counts[b])
        if n == 0:
            return WalkBatch.empty(), np.zeros(0, np.int64)
        with open(self.record_path(b), "rb") as f:
            raw = f.read()
        packed = np.frombuffer(raw, dtype=np.uint32).reshape(-1, 4)
        assert packed.shape[0] == n, "record file out of sync with pool counts"
        with open(self._wid_path(b), "rb") as f:
            wid = np.frombuffer(f.read(), dtype=np.int64)
        batch = unpack_walks(packed, self.block_starts)
        if consume:
            os.remove(self.record_path(b))
            os.remove(self._wid_path(b))
            self._spilled_counts[b] = 0
        return batch, wid.copy()

    def close(self) -> None:
        """Remove this pool's spill files so an aborted run (e.g. a writer
        fault mid-slot) never orphans them — pool state is gone with the
        object either way.  Directories go too when the pool created them
        (a fresh temp dir, or the whole makedirs chain of a
        previously-nonexistent explicit path); a pre-existing directory is
        left in place.  Idempotent."""
        if self._tmpdir is not None:
            self._tmpdir.cleanup()
            self._tmpdir = None
            return
        for b in range(self.num_blocks):
            for path in (self.record_path(b), self._wid_path(b)):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass
        if self._created_root is not None:
            shutil.rmtree(self._created_root, ignore_errors=True)


class AsyncWalkPool:
    """Sequenced async persist path over any :class:`WalkPool` backend.

    Wraps a base pool with a single *writer thread* draining a bounded FIFO
    job queue.  Every ``push`` is assigned a monotonically-increasing ticket
    and enqueued; the writer applies jobs strictly in ticket order, so the
    base pool steps through **exactly** the state sequence a serial engine
    issuing the same op sequence would have produced — same buffer
    contents, same spill points, same charged walk I/O — just off the
    caller's critical path.

    ``drain_async`` is the pipeline's preload primitive: the drain job rides
    the same FIFO, so it observes precisely the pushes enqueued *before* it
    in program order (a deterministic prefix — no racy snapshot), loads the
    pool on the writer thread (optionally running a ``transform`` such as
    bucket splitting there too) and resolves a future with
    ``(payload, n_walks, n_spilled)``.  Because a pool preserves push order
    and a drain consumes a prefix, ``prefix-drain + later remainder-drain``
    concatenates to what one serial ``load`` at slot start would return —
    the *walks* are identical.  The walk-I/O *charges* are deterministic
    and backend-invariant but follow the drain points: a preload drains the
    write buffer earlier than a slot-start ``load`` would, so a
    flush-threshold crossing that straddles the preload point can spill in
    one mode and not the other — ``walk_bytes_written/read`` legitimately
    differ between the async pipeline and the no-preload serial reference
    (block and on-demand charges never do).

    ``counts``/``min_hop`` are tracked *eagerly* on the caller's thread
    (updated at enqueue time), so schedulers see the same sequential view of
    pending walks as with a raw pool.

    A writer-thread exception is latched: every queued and subsequent
    operation (``push``/``load``/``flush``/``barrier``) re-raises it on the
    calling thread, so a failed spill propagates out of ``Engine.run()``.
    ``close`` never raises and never hangs: it wakes the writer, lets it
    drain the queue (failing pending futures once an error is latched) and
    joins it before closing the base pool.  Idempotent.
    """

    def __init__(self, base: WalkPool, stats: Optional[IOStats] = None, max_queue: int = 64):
        self.base = base
        self.stats = stats
        self.max_queue = max(int(max_queue), 1)
        self.num_blocks = base.num_blocks
        #: eager sequential view — the base arrays lag by the queue contents
        self.counts = base.counts.copy()
        self.min_hop = base.min_hop.copy()
        self.tickets_issued = 0
        self.applied_ticket = 0
        #: pool-local high-water copy of ``IOStats.writer_queue_peak`` for
        #: stats-less construction; both update from the same _enqueue line
        self.queue_peak = 0
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._error: Optional[BaseException] = None
        self._closed = False
        self._worker = threading.Thread(
            target=self._run_worker, name="walkpool-writer", daemon=True
        )
        self._worker.start()

    @property
    def backend(self) -> str:
        return self.base.backend

    def __getattr__(self, name):
        # forward backend extras (e.g. DiskWalkPool.bytes_written/on_disk_bytes)
        return getattr(self.base, name)

    # -- writer thread --------------------------------------------------------
    def _run_worker(self) -> None:
        while True:
            with self._cv:
                while not self._q and not self._closed:
                    self._cv.wait()
                if not self._q:
                    return  # closed and fully drained
                job = self._q.popleft()
                self._cv.notify_all()  # wake producers blocked on a full queue
            self._apply(job)

    def _apply(self, job) -> None:
        kind, fut = job[0], job[-1]
        if self._error is not None:
            if fut is not None:
                fut.set_exception(self._error)
            return
        try:
            if kind == "push":
                _, ticket, b, batch, wid, _ = job
                self.base.push(b, batch, wid)
                self.applied_ticket = ticket
            elif kind == "drain":
                _, b, transform, fut = job
                n_spilled = self.base._spilled_count(b)
                batch, wid = self.base.load(b)
                payload = transform(batch, wid) if transform is not None else (batch, wid)
                fut.set_result((payload, len(batch), n_spilled))
            elif kind == "flush":
                _, b, fut = job
                self.base.flush(b)
                fut.set_result(None)
            else:  # barrier
                fut.set_result(None)
        except BaseException as e:  # latch and surface on the calling thread
            self._error = e
            if fut is not None and not fut.done():
                fut.set_exception(e)
            with self._cv:
                self._cv.notify_all()

    # -- producer side --------------------------------------------------------
    def _raise_if_failed(self) -> None:
        if self._error is not None:
            raise RuntimeError("walk-pool writer thread failed") from self._error

    def _enqueue(self, job) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncWalkPool is closed")
            self._q.append(job)
            self.queue_peak = max(self.queue_peak, len(self._q))
            if self.stats is not None:
                self.stats.note_writer_queue(len(self._q))
            self._cv.notify_all()

    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        self._raise_if_failed()
        with self._cv:
            while len(self._q) >= self.max_queue and self._error is None and not self._closed:
                self._cv.wait()
        self._raise_if_failed()
        self.tickets_issued += 1
        self._enqueue(("push", self.tickets_issued, int(b), batch, wid, None))
        self.counts[b] += len(batch)
        self.min_hop[b] = min(self.min_hop[b], float(batch.hop.min()))

    def drain_async(
        self,
        b: int,
        transform: Optional[Callable[[WalkBatch, np.ndarray], object]] = None,
    ) -> Future:
        """Enqueue a prefix drain of pool ``b``; resolves to
        ``(payload, n_walks, n_spilled)`` where ``payload`` is
        ``transform(batch, wid)`` (or the raw pair)."""
        fut: Future = Future()
        self._enqueue(("drain", int(b), transform, fut))
        self.counts[b] = 0
        self.min_hop[b] = np.inf
        return fut

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        payload, _, _ = self.drain_async(b).result()
        return payload

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        """Inspect pool ``b`` after the queue settles (tests/debug; does not
        see batches already handed out by :meth:`drain_async`)."""
        self.barrier()
        return self.base.peek(b)

    def flush(self, b: Optional[int] = None) -> None:
        fut: Future = Future()
        self._enqueue(("flush", b, fut))
        fut.result()

    def barrier(self) -> None:
        """Block until every enqueued job has been applied; re-raises a
        latched writer error."""
        with self._cv:
            closed = self._closed
        if not closed:
            fut: Future = Future()
            self._enqueue(("barrier", fut))
            fut.result()
        self._raise_if_failed()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join()
        self.base.close()


class _ShardStats:
    """Stats facade handed to one shard's base pool.

    Base pools charge walk I/O through ``stats.walk_io`` from their shard's
    writer thread; this facade forwards the charge to the shared
    :class:`~repro.core.stats.IOStats` (which serialises concurrent shard
    writers under its lock) and stamps it with the shard id, feeding the
    ``shard_spill_bytes`` breakdown.
    """

    def __init__(self, parent: IOStats, shard: int):
        self.parent = parent
        self.shard = shard

    def walk_io(self, n_walks: int, *, bytes_per_walk: int = 16, kind: str = "write") -> None:
        self.parent.walk_io(n_walks, bytes_per_walk=bytes_per_walk, kind=kind, shard=self.shard)


class ShardedWalkPool:
    """Partition of the walk-pool keyspace across N sequenced writers.

    The ``(block, bucket)`` keyspace engines persist with is partitioned by
    :func:`shard_of_block` — a deterministic hash of the block id — across
    ``num_shards`` shards.  Each shard is a full pool backend
    (memory/disk, its own spill directory) wrapped in its own
    :class:`AsyncWalkPool` sequenced writer, so persists and
    ``drain_async`` preloads for blocks owned by *different* shards proceed
    concurrently with no cross-shard ordering, while per-shard FIFO ticket
    order is preserved.

    Determinism is inherited, not re-argued: every op on block ``b``
    (push, drain, flush) is forwarded to ``shard_of_block(b)``'s FIFO in
    program order, so a block's op subsequence — and with it the per-block
    write buffer, its spill points, and the prefix a ``drain_async``
    observes — is *identical* to what a single sequenced writer would
    apply.  Walks, walk-I/O charges, and the per-shard spill breakdown
    (``IOStats.shard_spill_bytes``, summing to ``walk_bytes_written``) are
    therefore invariant across shard counts and pool backends; only the
    concurrency changes.  The ``shard_imbalance`` gauge (max-over-mean of
    pushed walks per shard) is likewise a pure function of the push totals.

    ``counts``/``min_hop`` are tracked eagerly on the caller's thread —
    the same sequential view of pending walks :class:`AsyncWalkPool`
    exposes.  A writer fault in *any* shard latches and re-raises from
    every subsequent pool op and from :meth:`barrier`; ``close`` joins all
    writers and never raises or hangs.
    """

    def __init__(
        self,
        backend: str,
        *,
        num_shards: int,
        num_blocks: int,
        stats: IOStats,
        block_starts: Optional[np.ndarray] = None,
        flush_walks: Optional[int] = 1 << 18,
        directory: Optional[str] = None,
        max_queue: int = 64,
    ):
        if not isinstance(backend, str):
            raise ValueError("ShardedWalkPool builds its shards itself; pass a backend name")
        self.num_shards = max(int(num_shards), 1)
        self.num_blocks = num_blocks
        self.stats = stats
        self.counts = np.zeros(num_blocks, np.int64)
        self.min_hop = np.full(num_blocks, np.inf)
        self.owner = np.array(
            [shard_of_block(b, self.num_shards) for b in range(num_blocks)], np.int64
        )
        self.pushed_per_shard = np.zeros(self.num_shards, np.int64)
        # shard pools remove their own spill subdirs on close; any parent
        # chain we are about to create is ours to remove too
        self.directory = directory
        self._created_root = None if directory is None else _first_missing_ancestor(directory)
        self.shards: List[AsyncWalkPool] = []
        for k in range(self.num_shards):
            sub = None if directory is None else os.path.join(directory, f"shard_{k:02d}")
            base = make_walk_pool(
                backend,
                num_blocks=num_blocks,
                stats=_ShardStats(stats, k),
                block_starts=block_starts,
                flush_walks=flush_walks,
                directory=sub,
            )
            self.shards.append(AsyncWalkPool(base, stats=stats, max_queue=max_queue))
        self._closed = False

    @property
    def backend(self) -> str:
        return self.shards[0].backend

    def shard_of(self, b: int) -> int:
        return int(self.owner[b])

    def writer(self, b: int) -> AsyncWalkPool:
        """The sequenced writer owning block ``b``'s pool (the pipeline
        targets it for next-slot drains)."""
        return self.shards[self.shard_of(b)]

    def _raise_if_failed(self) -> None:
        for shard in self.shards:
            if shard._error is not None:
                raise RuntimeError("walk-pool shard writer failed") from shard._error

    # -- the engine-facing API ------------------------------------------------
    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        self._raise_if_failed()
        k = self.shard_of(b)
        self.shards[k].push(b, batch, wid)
        self.counts[b] += len(batch)
        self.min_hop[b] = min(self.min_hop[b], float(batch.hop.min()))
        self.pushed_per_shard[k] += len(batch)
        total = int(self.pushed_per_shard.sum())
        self.stats.note_shard_imbalance(
            int(self.pushed_per_shard.max()) * self.num_shards / max(total, 1)
        )

    def drain_async(
        self,
        b: int,
        transform: Optional[Callable[[WalkBatch, np.ndarray], object]] = None,
    ) -> Future:
        self._raise_if_failed()
        fut = self.writer(b).drain_async(b, transform)
        self.counts[b] = 0
        self.min_hop[b] = np.inf
        return fut

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        payload, _, _ = self.drain_async(b).result()
        return payload

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        return self.writer(b).peek(b)

    def flush(self, b: Optional[int] = None) -> None:
        if b is not None:
            self.writer(b).flush(b)
            return
        for shard in self.shards:
            shard.flush(None)

    def barrier(self) -> None:
        """Wait out every shard's writer queue; re-raises any latched fault."""
        for shard in self.shards:
            shard.barrier()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for shard in self.shards:
            shard.close()
        if self._created_root is not None:
            shutil.rmtree(self._created_root, ignore_errors=True)

    # -- disk-backend extras, aggregated over shards ---------------------------
    @property
    def bytes_written(self) -> int:
        return sum(getattr(s.base, "bytes_written", 0) for s in self.shards)

    def on_disk_bytes(self) -> int:
        return sum(s.base.on_disk_bytes() for s in self.shards if hasattr(s.base, "on_disk_bytes"))


def make_walk_pool(
    backend,
    *,
    num_blocks: int,
    stats: IOStats,
    block_starts: Optional[np.ndarray] = None,
    flush_walks: Optional[int] = 1 << 18,
    directory: Optional[str] = None,
) -> WalkPool:
    """Build a pool from a backend name, or pass an instance through."""
    if not isinstance(backend, str):
        return backend
    if backend == "memory":
        return MemoryWalkPool(num_blocks, stats, flush_walks)
    if backend == "disk":
        if block_starts is None:
            raise ValueError("disk pool needs block_starts for the 128-bit encoding")
        return DiskWalkPool(num_blocks, stats, block_starts, flush_walks, directory)
    raise ValueError(f"unknown walk pool backend {backend!r}; have memory, disk")
