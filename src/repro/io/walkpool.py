"""Walk pools — the "disk" tier for partially-finished walks (paper §4.3/§6.1).

A :class:`WalkPool` owns one append-only pool per block.  Engines ``push``
walks to the pool of the block they persist with (skewed ``min(B(u), B(v))``
or traditional ``B(cur)`` association — the *engine* decides the key, the
pool only stores) and ``load`` drains a whole pool at the start of that
block's time slot.

Both backends buffer pushes in memory and *spill* once a block's buffer
reaches ``flush_walks`` (the paper's walk-pool write buffer); a ``load``
first seals the buffer, then returns spilled + buffered walks in exact push
order, so the two backends are observationally identical to the engines:

* :class:`MemoryWalkPool` — spills into a host-memory list; the spill/read
  I/O is *modelled* (charged to :class:`~repro.core.stats.IOStats`) but no
  bytes move.  This is the seed engine's behavior, extracted.
* :class:`DiskWalkPool` — spills real 16-byte packed records
  (:func:`repro.core.walk.pack_walks`, §6.1 Fig. 7) to one append-only file
  per block, so ``IOStats.walk_bytes_written`` equals bytes on disk.  Walk
  ids ride in an int64 sidecar file: they are host bookkeeping for corpus
  recording, not part of the paper's record, and are not charged.

Only spilled walks are charged: a walk that never left the write buffer
never crossed the slow/fast boundary.  ``flush_walks=0`` spills every push
(the seed's accounting), ``flush_walks=None`` never spills before a load.
"""

from __future__ import annotations

import os
import tempfile
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.stats import IOStats
from repro.core.walk import WALK_BYTES, WalkBatch, pack_walks, unpack_walks

__all__ = ["WalkPool", "MemoryWalkPool", "DiskWalkPool", "make_walk_pool"]

_WID_BYTES = 8


@runtime_checkable
class WalkPool(Protocol):
    """Per-block walk storage; see the module docstring for the contract."""

    backend: str
    counts: np.ndarray  # [NB] int64 — walks currently stored per block
    min_hop: np.ndarray  # [NB] float64 — min hop per block (inf when empty)

    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None: ...

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]: ...

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]: ...

    def flush(self, b: Optional[int] = None) -> None: ...

    def close(self) -> None: ...


class _PoolBase:
    """Shared buffering, counting and spill-threshold logic."""

    backend = "base"

    def __init__(self, num_blocks: int, stats: IOStats, flush_walks: Optional[int] = 1 << 18):
        self.num_blocks = num_blocks
        self.stats = stats
        self.flush_walks = flush_walks
        self.counts = np.zeros(num_blocks, np.int64)
        self.min_hop = np.full(num_blocks, np.inf)
        self._buf: Dict[int, List[Tuple[WalkBatch, np.ndarray]]] = {
            b: [] for b in range(num_blocks)
        }
        self._buf_counts = np.zeros(num_blocks, np.int64)

    # -- subclass hooks -------------------------------------------------------
    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        raise NotImplementedError

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        raise NotImplementedError

    def _spilled_count(self, b: int) -> int:
        raise NotImplementedError

    # -- the engine-facing API ------------------------------------------------
    def push(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        self._buf[b].append((batch, wid))
        self._buf_counts[b] += len(batch)
        self.counts[b] += len(batch)
        self.min_hop[b] = min(self.min_hop[b], float(batch.hop.min()))
        if self.flush_walks is not None and self._buf_counts[b] >= self.flush_walks:
            self.flush(b)

    def flush(self, b: Optional[int] = None) -> None:
        """Spill buffered walks to the slow tier (charged as walk writes)."""
        blocks = range(self.num_blocks) if b is None else (b,)
        for blk in blocks:
            entries = self._buf[blk]
            if not entries:
                continue
            self._buf[blk] = []
            n = int(self._buf_counts[blk])
            self._buf_counts[blk] = 0
            batch = WalkBatch.concat([e[0] for e in entries])
            wid = np.concatenate([e[1] for e in entries])
            self._spill(blk, batch, wid)
            self.stats.walk_io(n, kind="write")

    def load(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        """Drain pool ``b``: spilled walks (charged as a read) + buffer."""
        n_spilled = self._spilled_count(b)
        spilled_batch, spilled_wid = self._read_spilled(b, consume=True)
        if n_spilled:
            self.stats.walk_io(n_spilled, kind="read")
        entries = self._buf[b]
        self._buf[b] = []
        self._buf_counts[b] = 0
        self.counts[b] = 0
        self.min_hop[b] = np.inf
        batch = WalkBatch.concat([spilled_batch] + [e[0] for e in entries])
        wid = np.concatenate([spilled_wid] + [e[1] for e in entries])
        return batch, wid

    def peek(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        """Inspect pool ``b`` without consuming or charging (tests/debug)."""
        spilled_batch, spilled_wid = self._read_spilled(b, consume=False)
        entries = self._buf[b]
        batch = WalkBatch.concat([spilled_batch] + [e[0] for e in entries])
        wid = np.concatenate([spilled_wid] + [e[1] for e in entries])
        return batch, wid

    def close(self) -> None:
        pass


class MemoryWalkPool(_PoolBase):
    """Host-memory pools; spill I/O is modelled, not performed."""

    backend = "memory"

    def __init__(self, num_blocks: int, stats: IOStats, flush_walks: Optional[int] = 1 << 18):
        super().__init__(num_blocks, stats, flush_walks)
        self._spilled: Dict[int, List[Tuple[WalkBatch, np.ndarray]]] = {
            b: [] for b in range(num_blocks)
        }
        self._spilled_counts = np.zeros(num_blocks, np.int64)

    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        self._spilled[b].append((batch, wid))
        self._spilled_counts[b] += len(batch)

    def _spilled_count(self, b: int) -> int:
        return int(self._spilled_counts[b])

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        entries = self._spilled[b]
        if consume:
            self._spilled[b] = []
            self._spilled_counts[b] = 0
        if not entries:
            return WalkBatch.empty(), np.zeros(0, np.int64)
        return (
            WalkBatch.concat([e[0] for e in entries]),
            np.concatenate([e[1] for e in entries]),
        )


class DiskWalkPool(_PoolBase):
    """Real per-block append-only files of 16-byte packed walk records."""

    backend = "disk"

    def __init__(
        self,
        num_blocks: int,
        stats: IOStats,
        block_starts: np.ndarray,
        flush_walks: Optional[int] = 1 << 18,
        directory: Optional[str] = None,
    ):
        super().__init__(num_blocks, stats, flush_walks)
        self.block_starts = np.asarray(block_starts, dtype=np.int64)
        self._tmpdir: Optional[tempfile.TemporaryDirectory] = None
        if directory is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="grasorw_pool_")
            directory = self._tmpdir.name
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self._spilled_counts = np.zeros(num_blocks, np.int64)
        self.bytes_written = 0

    def record_path(self, b: int) -> str:
        return os.path.join(self.directory, f"pool_{b:05d}.walks")

    def _wid_path(self, b: int) -> str:
        return os.path.join(self.directory, f"pool_{b:05d}.wid")

    def on_disk_bytes(self) -> int:
        """Current total size of all record files (16 bytes per stored walk)."""
        return sum(
            os.path.getsize(p)
            for b in range(self.num_blocks)
            if os.path.exists(p := self.record_path(b))
        )

    def _spill(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        packed = pack_walks(batch, self.block_starts)
        with open(self.record_path(b), "ab") as f:
            f.write(packed.tobytes())
        with open(self._wid_path(b), "ab") as f:
            f.write(np.asarray(wid, dtype=np.int64).tobytes())
        self._spilled_counts[b] += len(batch)
        self.bytes_written += len(batch) * WALK_BYTES

    def _spilled_count(self, b: int) -> int:
        return int(self._spilled_counts[b])

    def _read_spilled(self, b: int, *, consume: bool) -> Tuple[WalkBatch, np.ndarray]:
        n = int(self._spilled_counts[b])
        if n == 0:
            return WalkBatch.empty(), np.zeros(0, np.int64)
        with open(self.record_path(b), "rb") as f:
            raw = f.read()
        packed = np.frombuffer(raw, dtype=np.uint32).reshape(-1, 4)
        assert packed.shape[0] == n, "record file out of sync with pool counts"
        with open(self._wid_path(b), "rb") as f:
            wid = np.frombuffer(f.read(), dtype=np.int64)
        batch = unpack_walks(packed, self.block_starts)
        if consume:
            os.remove(self.record_path(b))
            os.remove(self._wid_path(b))
            self._spilled_counts[b] = 0
        return batch, wid.copy()

    def close(self) -> None:
        if self._tmpdir is not None:
            self._tmpdir.cleanup()
            self._tmpdir = None


def make_walk_pool(
    backend,
    *,
    num_blocks: int,
    stats: IOStats,
    block_starts: Optional[np.ndarray] = None,
    flush_walks: Optional[int] = 1 << 18,
    directory: Optional[str] = None,
) -> WalkPool:
    """Build a pool from a backend name, or pass an instance through."""
    if not isinstance(backend, str):
        return backend
    if backend == "memory":
        return MemoryWalkPool(num_blocks, stats, flush_walks)
    if backend == "disk":
        if block_starts is None:
            raise ValueError("disk pool needs block_starts for the 128-bit encoding")
        return DiskWalkPool(num_blocks, stats, block_starts, flush_walks, directory)
    raise ValueError(f"unknown walk pool backend {backend!r}; have memory, disk")
