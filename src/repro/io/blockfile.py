"""On-disk block container — the paper's Start-Vertex/Index/CSR files (Fig. 2)
packed into one file, plus a file-backed ``BlockedGraph`` twin.

Until this module existed, ``BlockedGraph.materialize_block`` cut blocks out
of a host-RAM CSR, so the metered "disk I/O" never touched a file descriptor.
:func:`write_block_file` serialises a :class:`~repro.core.graph.BlockedGraph`
into a single packed container with an offset index, and
:class:`DiskBlockedGraph` reads it back exposing the *same*
``materialize_block``/metadata surface — engines and the
:class:`~repro.io.blockstore.BlockStore` run unchanged and bit-identical,
but every full block load is now a real ``pread`` whose byte count equals
``ResidentBlock.nbytes_full()``, and on-demand loads are real per-vertex
partial reads whose byte count equals
:func:`~repro.core.graph.activated_bytes`.

Byte-level layout (everything little-endian)::

    offset  size                 field
    ------  -------------------  ----------------------------------------
    0       8                    magic  b"GRSWBLK1"
    8       4                    version (u32, =1)
    12      4                    flags (u32; bit 0: weights+alias present)
    16      8                    num_blocks  NB (u64)
    24      8                    num_vertices V (u64)
    32      8                    num_edges    E (u64)
    40      8                    max_block_verts (u64)
    48      8                    max_block_edges (u64)
    56      8                    reserved (u64, 0)
    64      (NB+1)*8             block_starts   (i64)  — Start Vertex File
    .       (NB+1)*8             block_offsets  (u64)  — byte offset of each
                                 block payload; last entry == file size
    .       V*4                  degrees (u32)        — per-vertex out-degree

    per block b, at block_offsets[b]:
      (nv+1)*4                   local indptr (i32)   — Index File slice
      ne*4                       global indices (i32) — CSR File slice
      [ne*4]                     edge weights (f32)       } only when
      [ne*4]                     alias_j, local (i32)     } flags bit 0
      [ne*4]                     alias_q (f32)            } is set

The charged quantities only ever count the Index + CSR slices (4-byte
cells), exactly like the in-RAM backend; weights/alias are derived data and
are tallied separately in :attr:`DiskBlockedGraph.aux_bytes_read`.
"""

from __future__ import annotations

import os
import struct
import tempfile
from typing import Dict, Iterable, Optional

import numpy as np

from repro.core.graph import (
    BlockedGraph,
    BlockView,
    CSRGraph,
    ResidentBlock,
    activated_bytes,
    block_of,
)
from repro.io.ioplan import execute_plan, plan_reads

__all__ = [
    "BLOCK_FILE_NAME",
    "BlockFileError",
    "DiskBlockedGraph",
    "write_and_open",
    "write_block_file",
]

MAGIC = b"GRSWBLK1"
VERSION = 1
FLAG_WEIGHTED = 1 << 0
_HEADER = struct.Struct("<8sII6Q")  # magic, version, flags, NB, V, E, maxv, maxe, rsvd
#: conventional file name inside a ``--graph-dir`` directory
BLOCK_FILE_NAME = "graph.grb"


class BlockFileError(RuntimeError):
    """Malformed, truncated, or version-incompatible block container."""


def write_block_file(bg: BlockedGraph, path: str) -> dict:
    """Serialise ``bg`` (an in-RAM blocked graph) into one packed container.

    Alias tables are built here with the exact builder the RAM backend uses
    (:func:`repro.core.sampling.build_alias_rows`), so a weighted graph read
    back from disk produces bit-identical walks.  Returns a small summary
    dict (``path``, ``file_bytes``, ``data_bytes``).
    """
    g = bg.graph
    nb = bg.num_blocks
    i32max = np.iinfo(np.int32).max
    if g.num_vertices > i32max or int(bg.max_block_edges) > i32max:
        # indices hold vertex ids, indptr holds within-block edge offsets —
        # both are 4-byte cells (the paper's layout); fail loudly instead of
        # wrapping negative and writing a corrupt-but-validating container
        raise BlockFileError(
            "graph exceeds the 4-byte cell format: need num_vertices and "
            "per-block edge counts <= int32 max"
        )
    weighted = g.weights is not None
    flags = FLAG_WEIGHTED if weighted else 0
    block_starts = bg.block_starts.astype(np.int64)
    degrees = g.degrees.astype(np.uint32)

    header = _HEADER.pack(
        MAGIC,
        VERSION,
        flags,
        nb,
        g.num_vertices,
        g.num_edges,
        bg.max_block_verts,
        bg.max_block_edges,
        0,
    )
    meta_bytes = _HEADER.size + 2 * 8 * (nb + 1) + 4 * g.num_vertices

    # offset index: payload sizes are fully determined by nverts/nedges
    per_edge = 4 + (12 if weighted else 0)  # indices + [weights, alias_j, alias_q]
    sizes = 4 * (bg.block_nverts + 1) + per_edge * bg.block_nedges
    block_offsets = np.zeros(nb + 1, dtype=np.uint64)
    block_offsets[0] = meta_bytes
    np.cumsum(sizes, out=block_offsets[1:].view(np.int64))
    block_offsets[1:] += np.uint64(meta_bytes)

    # unique temp in the destination directory (atomic publish, concurrent
    # writers to the same path never share a temp file), removed on any error
    fd, tmp_path = tempfile.mkstemp(
        prefix=os.path.basename(path) + ".",
        suffix=".tmp",
        dir=os.path.dirname(os.path.abspath(path)),
    )
    data_bytes = 0
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(header)
            f.write(block_starts.tobytes())
            f.write(block_offsets.tobytes())
            f.write(degrees.tobytes())
            for b in range(nb):
                s, e = int(block_starts[b]), int(block_starts[b + 1])
                es, ee = int(g.indptr[s]), int(g.indptr[e])
                nv, ne = e - s, ee - es
                indptr = (g.indptr[s : e + 1] - es).astype(np.int32)
                indices = g.indices[es:ee].astype(np.int32)
                f.write(indptr.tobytes())
                f.write(indices.tobytes())
                data_bytes += 4 * (nv + 1) + 4 * ne
                if weighted:
                    from repro.core.sampling import build_alias_rows

                    w = g.weights[es:ee].astype(np.float32)
                    aj, aq = build_alias_rows(indptr, nv, max(ne, 1), w)
                    f.write(w.tobytes())
                    f.write(aj[:ne].astype(np.int32).tobytes())
                    f.write(aq[:ne].astype(np.float32).tobytes())
            file_bytes = f.tell()
        if file_bytes != int(block_offsets[-1]):
            raise BlockFileError(
                f"writer bug: produced {file_bytes} bytes, offset index says "
                f"{int(block_offsets[-1])}"
            )
        os.replace(tmp_path, path)
    except BaseException:
        if os.path.exists(tmp_path):
            os.remove(tmp_path)
        raise
    return {"path": path, "file_bytes": file_bytes, "data_bytes": data_bytes}


class DiskBlockedGraph:
    """File-backed twin of :class:`~repro.core.graph.BlockedGraph`.

    Exposes the backend-neutral surface engines and the
    :class:`~repro.io.blockstore.BlockStore` consume — ``block_starts``,
    ``num_blocks``, ``block_nverts``/``block_nedges``, the padded-shape
    maxima, ``materialize_block``, ``activated_load_bytes`` — but every
    block materialisation is a real positioned read (``os.pread``) against
    the packed container.  Only the offset index, ``block_starts`` and the
    per-vertex degree array live in RAM (the paper keeps the same metadata
    resident); the CSR payload never does, so graphs larger than host
    memory are representable.

    Real-I/O counters (never charged to :class:`~repro.core.stats.IOStats`
    — the *engine* charges deterministically, these verify it):

    * ``data_bytes_read`` — Index+CSR bytes read by full loads; equal to the
      sum of ``nbytes_full()`` over those loads.
    * ``aux_bytes_read`` — weight/alias bytes read by full loads.
    * ``ondemand_bytes_read`` — *useful* bytes read by :meth:`read_rows` /
      :meth:`partial_block`; equal to ``activated_load_bytes`` of the
      requested vertices whatever the coalescing gap.
    * ``ondemand_syscalls`` / ``coalesced_ranges`` / ``coalesce_waste_bytes``
      — what the on-demand read path actually issued: every ``pread``
      counts toward ``ondemand_syscalls``; with the gap-aware planner on
      (``io_coalesce_gap > 0``) each coalesced range is one syscall and the
      read-through hole bytes accumulate as waste.  These mirror the
      :class:`~repro.core.stats.IOStats` gauges of the same names and match
      them exactly when prefetch is off.

    ``io_coalesce_gap`` is the planner's waste budget in bytes; 0 keeps the
    per-vertex reference reads bit-for-bit.
    """

    def __init__(self, path: str, *, io_coalesce_gap: int = 0):
        if os.path.isdir(path):
            path = os.path.join(path, BLOCK_FILE_NAME)
        self.path = path
        self.io_coalesce_gap = int(io_coalesce_gap)
        self._fd = -1  # so __del__/close are safe if os.open raises
        self._fd = os.open(path, os.O_RDONLY)
        try:
            self._load_metadata()
        except Exception:
            os.close(self._fd)
            self._fd = -1
            raise
        self.full_loads = 0
        self.ondemand_reads = 0
        self.data_bytes_read = 0
        self.aux_bytes_read = 0
        self.ondemand_bytes_read = 0
        self.ondemand_syscalls = 0
        self.coalesced_ranges = 0
        self.coalesce_waste_bytes = 0

    # -- open/close -----------------------------------------------------------
    def _load_metadata(self) -> None:
        raw = self._pread_exact(0, _HEADER.size, what="header")
        magic, version, flags, nb, V, E, maxv, maxe, _rsvd = _HEADER.unpack(raw)
        if magic != MAGIC:
            raise BlockFileError(f"bad magic {magic!r}: not a GraSorw block file")
        if version != VERSION:
            raise BlockFileError(f"unsupported block file version {version}")
        self.num_blocks = int(nb)
        self._num_vertices = int(V)
        self._num_edges = int(E)
        self.max_block_verts = int(maxv)
        self.max_block_edges = int(maxe)
        self.weighted = bool(flags & FLAG_WEIGHTED)
        off = _HEADER.size
        self.block_starts = np.frombuffer(
            self._pread_exact(off, 8 * (nb + 1), what="block_starts"), np.int64
        ).copy()
        off += 8 * (nb + 1)
        self.block_offsets = np.frombuffer(
            self._pread_exact(off, 8 * (nb + 1), what="block_offsets"), np.uint64
        ).copy()
        off += 8 * (nb + 1)
        self._degrees = np.frombuffer(
            self._pread_exact(off, 4 * V, what="degrees"), np.uint32
        ).astype(np.int64)
        if self.block_starts[0] != 0 or self.block_starts[-1] != V:
            raise BlockFileError("block_starts must span [0, V]")
        self.block_nverts = np.diff(self.block_starts).astype(np.int64)
        if np.any(self.block_nverts <= 0):
            raise BlockFileError("blocks must be non-empty, increasing")
        # global CSR offsets, reconstructed from degrees (RAM metadata)
        self._indptr = np.zeros(V + 1, dtype=np.int64)
        np.cumsum(self._degrees, out=self._indptr[1:])
        if self._indptr[-1] != E:
            raise BlockFileError("degree table inconsistent with num_edges")
        estarts = self._indptr[self.block_starts]
        self.block_nedges = np.diff(estarts).astype(np.int64)
        # the padded-shape maxima must equal the actual block maxima — the
        # shapes engines jit against, and the RAM backend's invariant
        if self.max_block_verts != int(self.block_nverts.max()) or (
            self.max_block_edges != max(int(self.block_nedges.max()), 1)
        ):
            raise BlockFileError("header block maxima inconsistent with blocks")
        per_edge = 4 + (12 if self.weighted else 0)
        sizes = 4 * (self.block_nverts + 1) + per_edge * self.block_nedges
        expect = np.diff(self.block_offsets.astype(np.int64))
        if not np.array_equal(expect, sizes):
            raise BlockFileError("offset index inconsistent with block sizes")
        if os.fstat(self._fd).st_size != int(self.block_offsets[-1]):
            raise BlockFileError(
                "file size does not match offset index (truncated or corrupt)"
            )

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1

    def __enter__(self) -> "DiskBlockedGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; close() is the real API
        try:
            self.close()
        except Exception:
            pass

    def _pread_exact(self, offset: int, n: int, *, what: str) -> bytes:
        raw = os.pread(self._fd, n, offset)
        if len(raw) != n:
            raise BlockFileError(
                f"truncated block file: wanted {n} bytes of {what} at offset "
                f"{offset}, got {len(raw)}"
            )
        return raw

    # -- backend-neutral metadata surface -------------------------------------
    @property
    def num_vertices(self) -> int:
        return self._num_vertices

    @property
    def num_edges(self) -> int:
        return self._num_edges

    @property
    def degrees(self) -> np.ndarray:
        return self._degrees

    @property
    def has_weights(self) -> bool:
        return self.weighted

    def ensure_alias(self) -> None:
        if not self.weighted:
            raise BlockFileError(
                "block file was written without weights/alias tables"
            )

    def block_id_of(self, v) -> np.ndarray:
        return block_of(self.block_starts, v)

    def activated_load_bytes(self, vertices: np.ndarray) -> int:
        return activated_bytes(self._degrees, vertices)

    def row_extents(self, vertices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Global CSR edge range ``[rs, re)`` per vertex of a sorted unique
        ``vertices`` array — resident metadata (the reconstructed degree
        cumsum), no I/O.  The read planner's input on either backend."""
        vs = np.asarray(vertices, dtype=np.int64)
        return self._indptr[vs], self._indptr[vs + 1]

    def describe(self) -> dict:
        return {
            "num_vertices": self._num_vertices,
            "num_edges": self._num_edges,
            "num_blocks": self.num_blocks,
            "max_block_verts": self.max_block_verts,
            "max_block_edges": self.max_block_edges,
            "csr_bytes": 4 * (self._num_vertices + 1 + self._num_edges),
            "edge_cut": self.edge_cut(),
        }

    def edge_cut(self) -> float:
        """Fraction of cross-block edges, computed by streaming every block
        (a metadata/debug pass: not counted against the read counters)."""
        cut = 0
        for b in range(self.num_blocks):
            _, indices, _ = self._read_block_arrays(b, count=False, want_aux=False)
            cut += int(np.sum(block_of(self.block_starts, indices) != b))
        return cut / max(self._num_edges, 1)

    # -- full-load path --------------------------------------------------------
    def _read_block_arrays(self, b: int, *, count: bool = True, want_aux: bool = True):
        """Read block ``b``'s raw Index + CSR slices (and aux arrays)."""
        if not 0 <= b < self.num_blocks:
            raise IndexError(f"block {b} out of range [0, {self.num_blocks})")
        nv = int(self.block_nverts[b])
        ne = int(self.block_nedges[b])
        off = int(self.block_offsets[b])
        raw = self._pread_exact(off, 4 * (nv + 1) + 4 * ne, what=f"block {b}")
        indptr = np.frombuffer(raw, np.int32, count=nv + 1)
        indices = np.frombuffer(raw, np.int32, count=ne, offset=4 * (nv + 1))
        aux = None
        if count:
            self.data_bytes_read += len(raw)
        if self.weighted and want_aux:
            araw = self._pread_exact(
                off + 4 * (nv + 1) + 4 * ne, 12 * ne, what=f"block {b} aux"
            )
            weights = np.frombuffer(araw, np.float32, count=ne)
            alias_j = np.frombuffer(araw, np.int32, count=ne, offset=4 * ne)
            alias_q = np.frombuffer(araw, np.float32, count=ne, offset=8 * ne)
            aux = (weights, alias_j, alias_q)
            if count:
                self.aux_bytes_read += len(araw)
        return indptr, indices, aux

    def materialize_block(self, b: int) -> ResidentBlock:
        """Full load: one positioned read of the block's Index + CSR slices,
        padded to the container-wide maxima (identical arrays to the RAM
        backend's ``materialize_block``).  No caching here — the
        :class:`~repro.io.blockstore.BlockStore` LRU is the resident set."""
        indptr_raw, indices_raw, aux = self._read_block_arrays(b)
        nv = int(self.block_nverts[b])
        ne = int(self.block_nedges[b])
        indptr = np.full(self.max_block_verts + 1, ne, dtype=np.int32)
        indptr[: nv + 1] = indptr_raw
        indices = np.full(self.max_block_edges, -1, dtype=np.int32)
        indices[:ne] = indices_raw
        blk = ResidentBlock(b, int(self.block_starts[b]), nv, ne, indptr, indices)
        self.full_loads += 1
        if aux is not None:
            _w, aj, aq = aux
            alias_j = np.zeros(self.max_block_edges, dtype=np.int32)
            alias_q = np.ones(self.max_block_edges, dtype=np.float32)
            alias_j[:ne] = aj
            alias_q[:ne] = aq
            blk.alias_j, blk.alias_q = alias_j, alias_q
        return blk

    # -- on-demand path --------------------------------------------------------
    def _read_rows_ext(self, b: int, vertices: Iterable[int]):
        """Partial reads of block ``b``'s requested rows — the access
        pattern of the paper's Fig. 5(b).

        With ``io_coalesce_gap == 0`` (reference): for each unique vertex,
        one ``pread`` of its 8-byte index-entry pair then one of its
        neighbor segment.  With the planner on: the index pairs are fetched
        by a few gap-split ranged reads over ``[min_v, max_v]`` of the index
        region, the resulting row extents merge into gap-aware coalesced
        ranges, and segments are sliced out in memory — same bytes charged,
        far fewer syscalls.  Returns ``(vs, rows, extents)`` with ``vs``
        sorted, ``rows[k]`` the global neighbor ids of ``vs[k]`` and
        ``extents[k] = (rs, re)`` its within-block edge range (reused by the
        alias reader so the index pair is never fetched twice)."""
        s, e = int(self.block_starts[b]), int(self.block_starts[b + 1])
        vs = np.unique(np.asarray(list(vertices), dtype=np.int64))
        if vs.size == 0:
            # no pread was issued: not an on-demand read, nothing to count
            return vs, [], []
        if vs[0] < s or vs[-1] >= e:
            raise IndexError(f"vertices outside block {b} range [{s}, {e})")
        nv = int(self.block_nverts[b])
        off = int(self.block_offsets[b])
        indices_off = off + 4 * (nv + 1)
        rows = []
        extents = []
        nbytes = 0
        if self.io_coalesce_gap > 0:
            read = lambda o, n: self._pread_exact(o, n, what=f"coalesced range block {b}")
            lv = vs - s
            iplan = plan_reads(4 * lv, 4 * lv + 8, self.io_coalesce_gap)
            pairs = execute_plan(iplan, read, base=off)
            rplan_s = np.empty(vs.size, np.int64)
            rplan_e = np.empty(vs.size, np.int64)
            for k, buf in enumerate(pairs):
                pair = np.frombuffer(buf, np.int32)
                rplan_s[k], rplan_e[k] = int(pair[0]), int(pair[1])
                extents.append((int(pair[0]), int(pair[1])))
            rplan = plan_reads(4 * rplan_s, 4 * rplan_e, self.io_coalesce_gap)
            for seg in execute_plan(rplan, read, base=indices_off):
                rows.append(np.frombuffer(seg, np.int32).copy())
            nbytes = 8 * vs.size + 4 * int((rplan_e - rplan_s).sum())
            nranges = iplan.num_ranges + rplan.num_ranges
            self.ondemand_syscalls += nranges
            self.coalesced_ranges += nranges
            self.coalesce_waste_bytes += iplan.waste_bytes + rplan.waste_bytes
        else:
            for v in vs:
                lv = int(v) - s
                pair = np.frombuffer(
                    self._pread_exact(off + 4 * lv, 8, what=f"index pair v={v}"),
                    np.int32,
                )
                rs, re = int(pair[0]), int(pair[1])
                nbytes += 8
                seg = self._pread_exact(indices_off + 4 * rs, 4 * (re - rs), what=f"row v={v}")
                rows.append(np.frombuffer(seg, np.int32).copy())
                extents.append((rs, re))
                nbytes += 4 * (re - rs)
            self.ondemand_syscalls += 2 * int(vs.size)
        self.ondemand_reads += 1
        self.ondemand_bytes_read += nbytes
        return vs, rows, extents

    def read_rows(self, b: int, vertices: Iterable[int]) -> Dict[int, np.ndarray]:
        """On-demand load: ``{vertex: global neighbor ids}`` for each unique
        requested vertex of block ``b``.  The bytes read equal
        ``activated_load_bytes(vertices)`` by construction."""
        vs, rows, _ = self._read_rows_ext(b, vertices)
        return {int(v): seg for v, seg in zip(vs, rows)}

    def partial_view(self, b: int, vertices: Iterable[int]) -> BlockView:
        """An *activated* :class:`~repro.core.graph.BlockView` of block
        ``b``: compacted local CSR over only the (unique) requested vertices
        plus the remap table — what on-demand buckets execute on.

        Index + CSR bytes are tallied in ``ondemand_bytes_read`` (equal to
        ``activated_load_bytes``); for a weighted container the rows' alias
        segments are read too (derived data, tallied in ``aux_bytes_read``
        like a full load's).  Mirrors ``BlockedGraph.partial_view`` — same
        view, real reads.
        """
        vs, segs, extents = self._read_rows_ext(b, vertices)
        alias_segs = None
        if self.weighted:
            alias_segs = self._read_alias_rows(b, vs, extents)
        return BlockView.from_rows(b, vs, segs, alias_segs)

    def gather_view(self, vertices: Iterable[int]) -> BlockView:
        """A cross-block activated view (``block_id == -1``): per-vertex
        partial reads grouped by owning block.  Blocks hold contiguous
        vertex ranges, so concatenating the per-block (sorted) rows in
        block order yields a globally sorted remap table.  Real bytes are
        tallied like any on-demand read."""
        vs_all = np.unique(np.asarray(list(vertices), dtype=np.int64))
        owners = block_of(self.block_starts, vs_all)
        all_vs = []
        all_segs = []
        all_alias = [] if self.weighted else None
        for b in np.unique(owners):
            sub = vs_all[owners == b]
            vs, segs, extents = self._read_rows_ext(int(b), sub)
            all_vs.append(vs)
            all_segs.extend(segs)
            if self.weighted:
                all_alias.extend(self._read_alias_rows(int(b), vs, extents))
        vids = np.concatenate(all_vs) if all_vs else np.zeros(0, np.int64)
        return BlockView.from_rows(-1, vids, all_segs, all_alias)

    def _read_alias_rows(self, b: int, vs: np.ndarray, extents):
        """Partial reads of the rows' alias_j/alias_q segments, at the edge
        ranges ``extents`` already fetched by :meth:`_read_rows_ext` — no
        second index-pair read per vertex.  With the planner on, the alias
        extents parallel the row extents, so one plan covers both regions
        (executed twice with different base offsets)."""
        ne = int(self.block_nedges[b])
        nv = int(self.block_nverts[b])
        off = int(self.block_offsets[b])
        aux_off = off + 4 * (nv + 1) + 4 * ne  # weights, then alias_j, alias_q
        out = []
        nbytes = 0
        if self.io_coalesce_gap > 0 and len(vs):
            read = lambda o, n: self._pread_exact(o, n, what=f"coalesced alias block {b}")
            rs = np.asarray([x for x, _ in extents], np.int64)
            re = np.asarray([x for _, x in extents], np.int64)
            aplan = plan_reads(4 * rs, 4 * re, self.io_coalesce_gap)
            j_bufs = execute_plan(aplan, read, base=aux_off + 4 * ne)
            q_bufs = execute_plan(aplan, read, base=aux_off + 8 * ne)
            for jb, qb in zip(j_bufs, q_bufs):
                out.append(
                    (np.frombuffer(jb, np.int32).copy(), np.frombuffer(qb, np.float32).copy())
                )
            nbytes = 8 * int((re - rs).sum())
            self.ondemand_syscalls += 2 * aplan.num_ranges
            self.coalesced_ranges += 2 * aplan.num_ranges
            self.coalesce_waste_bytes += 2 * aplan.waste_bytes
        else:
            for v, (rs, re) in zip(vs, extents):
                rl = re - rs
                aj = np.frombuffer(
                    self._pread_exact(aux_off + 4 * ne + 4 * rs, 4 * rl, what=f"alias_j v={v}"),
                    np.int32,
                ).copy()
                aq = np.frombuffer(
                    self._pread_exact(aux_off + 8 * ne + 4 * rs, 4 * rl, what=f"alias_q v={v}"),
                    np.float32,
                ).copy()
                out.append((aj, aq))
                nbytes += 8 * rl
            self.ondemand_syscalls += 2 * len(vs)
        self.aux_bytes_read += nbytes
        return out

    def partial_block(self, b: int, vertices: Iterable[int]) -> ResidentBlock:
        """An *activated-vertex view* of block ``b``: a padded
        :class:`ResidentBlock` holding only the requested rows, compacted.

        Rows that were not requested come back empty (degree 0); requested
        rows hold the same neighbor lists a full load would.  Reads only the
        requested bytes (tallied in ``ondemand_bytes_read``).
        """
        rows = self.read_rows(b, vertices)
        nv = int(self.block_nverts[b])
        s = int(self.block_starts[b])
        indptr = np.zeros(self.max_block_verts + 1, dtype=np.int32)
        chunks = []
        fill = 0
        for lv in range(nv):
            indptr[lv] = fill
            seg = rows.get(s + lv)
            if seg is not None:
                chunks.append(seg)
                fill += seg.size
        indptr[nv:] = fill
        indices = np.full(self.max_block_edges, -1, dtype=np.int32)
        if chunks:
            cat = np.concatenate(chunks)
            indices[: cat.size] = cat
        return ResidentBlock(b, s, nv, fill, indptr, indices)

    # -- reconstruction --------------------------------------------------------
    def read_csr(self) -> CSRGraph:
        """Stream every block back into one host-RAM :class:`CSRGraph`
        (weights included when present).  Debug/oracle path — requires the
        whole graph to fit in memory, which is exactly what this backend
        otherwise avoids."""
        indices = np.empty(self._num_edges, dtype=np.int32)
        weights = np.empty(self._num_edges, dtype=np.float32) if self.weighted else None
        pos = 0
        for b in range(self.num_blocks):
            _, idx, aux = self._read_block_arrays(b, count=False)
            indices[pos : pos + idx.size] = idx
            if aux is not None:
                weights[pos : pos + idx.size] = aux[0]
            pos += idx.size
        return CSRGraph(self._indptr.copy(), indices, weights)

    def counters(self) -> dict:
        return {
            "full_loads": self.full_loads,
            "ondemand_reads": self.ondemand_reads,
            "data_bytes_read": self.data_bytes_read,
            "aux_bytes_read": self.aux_bytes_read,
            "ondemand_bytes_read": self.ondemand_bytes_read,
            "ondemand_syscalls": self.ondemand_syscalls,
            "coalesced_ranges": self.coalesced_ranges,
            "coalesce_waste_bytes": self.coalesce_waste_bytes,
        }


def write_and_open(
    bg: BlockedGraph,
    directory: Optional[str] = None,
    *,
    name: str = BLOCK_FILE_NAME,
    io_coalesce_gap: int = 0,
) -> DiskBlockedGraph:
    """Serialise ``bg`` into ``directory`` and open the container — the
    one-call disk-backend bootstrap shared by the launcher
    (``--graph-backend disk``) and the benchmark harness.
    ``io_coalesce_gap`` sets the opened reader's gap-aware read-planner
    waste budget (0 = per-vertex reference reads).

    When ``directory`` is ``None`` a scratch dir is created and removed at
    interpreter exit; pass an explicit directory to keep the container
    around for reuse across runs.
    """
    if directory is None:
        import atexit

        scratch = tempfile.TemporaryDirectory(prefix="grasorw_graph_")
        atexit.register(scratch.cleanup)
        directory = scratch.name
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, name)
    write_block_file(bg, path)
    return DiskBlockedGraph(path, io_coalesce_gap=io_coalesce_gap)
