"""Storage/I/O subsystem: walk pools (the "disk" tier for walk state) and the
block store (resident-block cache + background prefetch).

Engines in :mod:`repro.engines` persist walks exclusively through a
:class:`WalkPool` backend and load graph blocks exclusively through a
:class:`BlockStore`; this package is the seam for sharded pools, async
bucket pipelines and multi-device walkers.
"""

from .blockstore import BlockStore
from .walkpool import DiskWalkPool, MemoryWalkPool, WalkPool, make_walk_pool

__all__ = [
    "BlockStore",
    "DiskWalkPool",
    "MemoryWalkPool",
    "WalkPool",
    "make_walk_pool",
]
