"""Storage/I/O subsystem: walk pools (the "disk" tier for walk state), the
block store (resident-block cache + background prefetch), and the on-disk
block container (:mod:`repro.io.blockfile`).

Engines in :mod:`repro.engines` persist walks exclusively through a
:class:`WalkPool` backend and load graph blocks exclusively through a
:class:`BlockStore`; the store serves either the in-RAM
:class:`repro.core.graph.BlockedGraph` or the file-backed
:class:`DiskBlockedGraph`, so this package is the seam for sharded pools,
async bucket pipelines, multi-device walkers, and graphs larger than host
memory.
"""

from .blockfile import (
    BLOCK_FILE_NAME,
    BlockFileError,
    DiskBlockedGraph,
    write_and_open,
    write_block_file,
)
from .blockstore import BlockStore
from .ioplan import ReadPlan, execute_plan, model_ondemand_io, plan_reads
from .walkpool import (
    AsyncWalkPool,
    DiskWalkPool,
    MemoryWalkPool,
    ShardedWalkPool,
    WalkPool,
    make_walk_pool,
    shard_of_block,
)

__all__ = [
    "AsyncWalkPool",
    "BLOCK_FILE_NAME",
    "BlockFileError",
    "BlockStore",
    "DiskBlockedGraph",
    "DiskWalkPool",
    "MemoryWalkPool",
    "ReadPlan",
    "ShardedWalkPool",
    "WalkPool",
    "execute_plan",
    "make_walk_pool",
    "model_ondemand_io",
    "plan_reads",
    "shard_of_block",
    "write_and_open",
    "write_block_file",
]
