"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Optimizer state is sharded exactly like the parameters (the rules in
sharding/rules.py put the big axes over ('data','model') — ZeRO-style), so
the update is purely element-wise and communication-free; the only
collective in the optimizer path is the scalar global-norm all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any  # fp32 copy of params
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    # copy=True: an f32 param leaf would otherwise ALIAS its master twin,
    # and donating params+opt_state together would donate one buffer twice
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamWState, params, cfg: OptConfig):
    """Returns (new_params_in_model_dtype, new_state, metrics).

    ``params`` supplies the model dtypes the new parameters are cast back to
    (bf16 compute / fp32 master split).
    """
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-20
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / gnorm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1t
        vh = v / b2t
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return (m, v, p)

    out = jax.tree.map(
        upd, grads, state.m, state.v, state.master,
    )
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    m = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
    v = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
    master = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    new_params = jax.tree.map(lambda mm, p: mm.astype(p.dtype), master, params)
    return (
        new_params,
        AdamWState(step=step, master=master, m=m, v=v),
        {"grad_norm": gnorm, "lr": lr},
    )
