from .adamw import AdamWState, OptConfig, adamw_init, adamw_update, lr_schedule

__all__ = ["AdamWState", "OptConfig", "adamw_init", "adamw_update", "lr_schedule"]
