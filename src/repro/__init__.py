"""GraSorw-JAX: I/O-efficient second-order random walks (the paper) +
a multi-pod LM training/serving framework that consumes them.

Subpackages: core (graph/buckets/scheduling/loading + stats), io (walk
pools + block store with prefetch), engines (bi-block system, baselines,
in-memory oracle), kernels (Pallas TPU), models, sharding, optim, train,
data, checkpoint, runtime, configs, launch.
"""

__version__ = "0.1.0"
