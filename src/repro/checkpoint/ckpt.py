"""Sharded checkpointing with atomic manifest commit + async writes.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json       # tree structure, shapes, dtypes, data cursor,
                            # mesh shape it was saved under, rng state
        arrays/<leaf-id>.npy

Design points for 1000+-node deployments (scaled to this container):
  * per-host shard writes — each host serialises only the addressable
    shards of its local devices (here: the single host writes everything,
    through the same code path, via ``jax.device_get`` per leaf);
  * atomic commit — arrays land in a tmp dir, the manifest is written last
    and the dir is renamed; a crash mid-write never yields a readable-but-
    corrupt checkpoint (restore scans for the latest *committed* step);
  * async — writes happen on a background thread so the train loop only
    blocks on the previous save (double-buffering), mirroring how real
    fleets hide checkpoint latency behind compute;
  * elastic restore — arrays are saved unsharded-logical (device_get), so
    a restore may target ANY mesh: the restore path re-device_puts against
    the new NamedShardings (resharding = the restore-time all-gather that
    elastic scaling requires).
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            getattr(p, "key", getattr(p, "idx", getattr(p, "name", str(p))))
            if not isinstance(p, jax.tree_util.SequenceKey)
            else str(p.idx)
            for p in path
        )
        key = key.replace("'", "")
        out[key] = leaf
    return out


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree,
    *,
    extra: Optional[dict] = None,
) -> Path:
    """Synchronous save with atomic commit. Returns the committed path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)
    flat = _flatten(tree)
    meta = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(getattr(leaf, "dtype", arr.dtype))
        if arr.dtype.kind == "V" or logical == "bfloat16":
            # numpy has no native bfloat16: persist the raw 2-byte lanes
            arr = arr.view(np.uint16)
            logical = "bfloat16"
        np.save(tmp / "arrays" / f"{i}.npy", arr)
        meta[key] = {"file": f"{i}.npy", "shape": list(arr.shape),
                     "dtype": logical}
    manifest = {"step": step, "arrays": meta, "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.glob("step_*"):
        if (p / "manifest.json").exists():  # committed only
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str | Path,
    tree_like,
    *,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[Any, dict]:
    """Restore into the structure of ``tree_like``; reshard if given
    ``shardings`` (a matching pytree of NamedSharding) — this is the elastic
    path: the target mesh may differ from the save-time mesh."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    manifest = json.loads((src / "manifest.json").read_text())
    flat_like = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    restored = {}
    for key, info in manifest["arrays"].items():
        if key not in flat_like:
            continue
        arr = np.load(src / "arrays" / info["file"])
        if info["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if shardings is not None and key in flat_sh:
            restored[key] = jax.device_put(arr, flat_sh[key])
        else:
            restored[key] = jax.numpy.asarray(arr)
    # rebuild the pytree in tree_like's structure
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    keys = list(_flatten(tree_like).keys())
    leaves = []
    for k, (_path, leaf) in zip(keys, flat):
        leaves.append(restored.get(k, leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Async double-buffered writer + retention."""

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None):
        self.wait()  # block on the previous save only
        tree = jax.tree.map(jax.device_get, tree)  # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, tree, extra=extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.directory.glob("step_*")
            if (p / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
