"""Train / serve step factories.

``make_train_step`` builds the jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` function: forward (+ MoE aux loss), backward,
AdamW with fp32 master, optional gradient accumulation over microbatches
(sequential scan — trades step latency for activation memory).  Donation of
params/opt_state is declared at jit time by the launcher.

``make_prefill_step`` / ``make_decode_step`` are the serving twins
(serve_step in the dry-run = one decode token against a full-length cache).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model_decode, model_forward, model_prefill
from repro.models.common import ModelConfig
from repro.optim import OptConfig, adamw_update
from .loss import lm_loss

__all__ = ["make_loss_fn", "make_train_step", "make_prefill_step", "make_decode_step"]

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = model_forward(params, batch, cfg)
        ce, n = lm_loss(logits, batch["labels"], cfg)
        loss = ce + AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig, *, microbatches: int = 1):
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), ms = jax.lax.scan(acc_body, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches = model_prefill(params, batch, cfg)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, sample: bool = False):
    def decode_step(params, batch, caches):
        logits, new_caches = model_decode(
            params, batch["token"], caches, batch["cache_len"], cfg
        )
        if sample:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_caches

    return decode_step
