from .loss import lm_loss
from .step import make_decode_step, make_loss_fn, make_prefill_step, make_train_step

__all__ = [
    "lm_loss", "make_decode_step", "make_loss_fn", "make_prefill_step",
    "make_train_step",
]
