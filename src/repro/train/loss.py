"""Cross-entropy over the padded vocab with ignore-index masking."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

__all__ = ["lm_loss"]

IGNORE = -1


def lm_loss(logits, labels, cfg: ModelConfig):
    """logits: [B, S, vocab_padded] (any float dtype); labels: [B, S] int32
    with IGNORE at masked positions. Returns (mean loss, token count)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries out of the softmax
    if cfg.vocab_padded > cfg.vocab_size:
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    valid = labels != IGNORE
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * valid
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, n
