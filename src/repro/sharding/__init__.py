from .rules import batch_specs, cache_specs, dp_axes, named, param_specs

__all__ = ["batch_specs", "cache_specs", "dp_axes", "named", "param_specs"]
