"""Ambient activation-sharding rules.

The model code is mesh-agnostic; the launcher publishes a {key ->
PartitionSpec} dict here and the model calls ``constrain(x, key)`` at the
few points that matter.  The big one: the layer-scan carry ("residual") —
without a constraint XLA saves one *unsharded* [B, S, D] residual per layer
for the backward pass (74 GB/device for yi-34b train_4k); sequence-sharding
it over `model` divides that by 16.

Keys used by the models:
  residual   — [B, S, D] embedding output / layer-scan carry
  logits     — [B, S, vocab_padded]
"""

from __future__ import annotations

import contextvars
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

__all__ = ["activation_rules", "constrain", "default_rules"]

_RULES: contextvars.ContextVar[Optional[Dict[str, PartitionSpec]]] = (
    contextvars.ContextVar("activation_rules", default=None)
)


@contextmanager
def activation_rules(rules: Optional[Dict[str, PartitionSpec]]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x, key: str):
    rules = _RULES.get()
    if not rules or key not in rules:
        return x
    spec = rules[key]
    # drop axes that don't divide the corresponding dim
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def get_rule(key: str, default=None):
    """Raw access to a published rule (non-PartitionSpec entries allowed)."""
    rules = _RULES.get()
    if not rules:
        return default
    return rules.get(key, default)


def default_rules(mesh, batch: int, seq: int, d_model: int):
    """Sequence-sharded residuals when divisible; batch over dp axes."""
    from .rules import dp_axes

    dp = dp_axes(mesh)
    import numpy as np

    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    b_ax = dp if (dp and batch % dp_n == 0) else None
    model_n = mesh.shape.get("model", 1)
    s_ax = "model" if seq % model_n == 0 else None
    return {
        "residual": PartitionSpec(b_ax, s_ax, None),
        "logits": PartitionSpec(b_ax, s_ax, None),
        # expert-parallel MoE dispatch (moe.py reads these raw entries)
        "moe_ep_axis": "model" if model_n > 1 else None,
        "moe_dp_axes": b_ax,
        "mesh": mesh,
    }
