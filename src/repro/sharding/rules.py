"""Logical->physical sharding rules for the production meshes.

Posture (DESIGN.md §4.1/§5): **no head-divisibility assumptions anywhere**.

* Parameters: ZeRO/FSDP-style — 2-D+ weights shard their input dim over
  `data` and output dim over `model` when divisible (both checked per leaf);
  embedding/lm-head shard the vocab dim over `model`; norms/biases/scalars
  replicate.  Optimizer state inherits the parameter specs (element-wise
  update = communication-free).
* Batches: batch dim over (`pod`, `data`) when divisible (long_500k has
  batch 1 — replicated), sequence unsharded at input (XLA propagates).
* Caches: KV/latent sequence dim over `model`; SSM/LRU state heads/width
  over `model`; batch over dp axes when divisible.

Everything returns `PartitionSpec`s; the launcher turns them into
NamedShardings against whichever mesh is active (1-pod or 2-pod).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

__all__ = [
    "dp_axes",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "named",
]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def named(mesh: Mesh, tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _weight_spec(
    shape, mesh: Mesh, path_str: str, cfg: ModelConfig, *, mode: str = "train"
) -> P:
    """Spec for one parameter leaf (shape may include a leading group dim).

    mode='train': ZeRO/FSDP posture — input dim over `data`, output over
    `model` (optimizer state forces the spread).
    mode='serve': weights replicate over `data` (no optimizer state; decode
    would otherwise all-gather every layer's weights every token — §Perf
    iteration 2 measured that as the entire collective term of decode_32k).
    """
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1) if mode == "train" else 10**9  # never divides
    dims = list(shape)
    lead = []
    if "segments" in path_str or "_layers" in path_str:
        lead = [None]  # stacked group axis stays unsharded
        dims = dims[1:]
    if len(dims) <= 1:  # norms, biases, scalars
        return P(*lead, *([None] * len(dims)))
    # embedding tables / positional tables / heads: vocab over 'model'
    if any(k in path_str for k in ("embed", "lm_head", "enc_pos", "dec_pos")):
        if "lm_head" in path_str:  # [D, V]
            spec = [None, "model" if dims[1] % model_n == 0 else None]
        else:  # [V, D]
            spec = ["model" if dims[0] % model_n == 0 else None, None]
        return P(*lead, *spec)
    if "router" in path_str:
        return P(*lead, *([None] * len(dims)))
    if "conv" in path_str:  # [W, C]: channel over model
        return P(*lead, None, "model" if dims[1] % model_n == 0 else None)
    if len(dims) == 3:  # stacked experts [E, in, out]
        if cfg.moe_shard_experts and dims[0] % model_n == 0:
            return P(*lead, "model", "data" if dims[1] % data_n == 0 else None, None)
        return P(
            *lead,
            None,
            "data" if dims[1] % data_n == 0 else None,
            "model" if dims[2] % model_n == 0 else None,
        )
    # generic 2-D weight [in, out]: FSDP over data, TP over model
    return P(
        *lead,
        "data" if dims[0] % data_n == 0 else None,
        "model" if dims[1] % model_n == 0 else None,
    )


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh, *, mode: str = "train"):
    """Pytree of PartitionSpec matching ``params_shape`` (ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        path_str = "/".join(str(p) for p in path)
        specs.append(_weight_spec(leaf.shape, mesh, path_str, cfg, mode=mode))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def _batch_axis(mesh: Mesh, batch: int):
    axes = dp_axes(mesh)
    if axes and batch % _axis_size(mesh, axes) == 0:
        return axes
    # try intra-pod data only
    if "data" in mesh.shape and batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, *, kind: str) -> Dict[str, P]:
    """Specs for the input batch dict of ``kind`` in {train, prefill, decode}."""
    b = _batch_axis(mesh, batch)
    if kind in ("train", "prefill"):
        specs: Dict[str, P] = {"tokens": P(b, None), "labels": P(b, None)}
        if cfg.frontend == "vision":
            specs["prefix"] = P(b, None, None)
        if cfg.is_encoder_decoder:
            specs["frames"] = P(b, None, None)
        if kind == "prefill":
            specs.pop("labels", None)
        return specs
    if kind == "decode":
        return {"token": P(b, None), "cache_len": P()}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, caches_shape, mesh: Mesh, batch: int):
    """Shard cache leaves: seq dim over 'model', batch over dp axes."""
    b = _batch_axis(mesh, batch)
    model_n = mesh.shape.get("model", 1)

    def spec_for(path, leaf) -> P:
        # every cache leaf is [n_groups/L, B, ...] (scan-stacked)
        shape = leaf.shape
        path_str = "/".join(str(p) for p in path)
        lead = [None]
        dims = list(shape[1:])
        spec = [b]  # batch dim
        rest = dims[1:]
        if "ckv" in path_str or path_str.endswith("k") or path_str.endswith("v"):
            # [B, L, ...]: shard L over model when divisible
            if rest and rest[0] % model_n == 0:
                spec.append("model")
                rest = rest[1:]
        elif "ssm" in path_str:
            # [B, nh, hd, ns]: shard heads over model when divisible
            if rest and rest[0] % model_n == 0:
                spec.append("model")
                rest = rest[1:]
        elif path_str.endswith("h"):
            # rglru [B, w]
            if rest and rest[0] % model_n == 0:
                spec.append("model")
                rest = rest[1:]
        elif "conv" in path_str:
            # [B, W-1, C]: shard channels
            if len(rest) == 2 and rest[1] % model_n == 0:
                spec.extend([None, "model"])
                rest = []
        spec.extend([None] * len(rest))
        return P(*lead, *spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat]
    )
