"""Production train launcher.

Assembles: config -> params (sharded init or checkpoint restore) -> data
(walk corpus) -> resilient step loop, against the production mesh.  On this
CPU container it runs reduced configs end-to-end (the full configs are
exercised via dryrun.py); on a TPU fleet the same file is the real
entry point — the mesh comes from jax.devices() topology.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
from pathlib import Path



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--graph-vertices", type=int, default=2000)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, reduced_config
    from repro.core import (
        BiBlockEngine,
        erdos_renyi,
        partition_into_n_blocks,
        rwnv_task,
    )
    from repro.data import WalkCorpus
    from repro.models import model_init
    from repro.optim import OptConfig, adamw_init
    from repro.runtime import ResilientTrainer
    from repro.train import make_train_step

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    print(f"arch={cfg.name} devices={jax.device_count()}")

    # data: walk corpus from the paper's engine
    g = erdos_renyi(args.graph_vertices, args.graph_vertices * 8, seed=0)
    bg = partition_into_n_blocks(g, 6)
    res = BiBlockEngine(bg, rwnv_task(walks_per_vertex=2, length=32),
                        record_walks=True).run()
    corpus = WalkCorpus.from_walks(res.corpus, g.num_vertices)
    print(f"corpus: {len(corpus):,} walks, vocab {corpus.vocab_size:,}")

    params = model_init(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step = jax.jit(
        make_train_step(cfg, opt_cfg, microbatches=args.microbatches),
        donate_argnums=(0, 1),
    )
    opt = adamw_init(params)
    trainer = ResilientTrainer(
        train_step=step, ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10),
        heartbeat_path=Path(args.ckpt_dir) / "heartbeat",
    )
    resumed = trainer.resume(params, opt)
    start, cursor = 0, 0
    if resumed:
        params, opt, start, cursor = resumed
        cursor = cursor or 0
        print(f"resumed at step {start}")

    def on_metrics(s, m):
        if s % 10 == 0:
            print(f"step {s:4d} loss {m['loss']:.4f} "
                  f"({m['step_time']*1e3:.0f} ms)")

    params, opt, info = trainer.run(
        params, opt,
        corpus.batches(args.batch, args.seq, cursor=cursor, seed=1),
        num_steps=args.steps, start_step=start, on_metrics=on_metrics,
    )
    print(f"finished at step {info['step']}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
