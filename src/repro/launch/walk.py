"""Walk-engine launcher: run a GraSorw task from the command line.

    PYTHONPATH=src python -m repro.launch.walk --task rwnv --vertices 5000 \
        --engine biblock [--engine sogw|sgsc|pb|oracle] [--p 4 --q 0.25] \
        [--graph-backend disk --graph-dir /path/to/dir] [--pool disk] \
        [--no-async-pipeline] [--writer-queue 64] [--pool-shards 4] \
        [--advance pallas]

Prints the paper's headline statistics (block/vertex/on-demand I/Os,
simulated I/O + exec time) as one CSV row per engine.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=("rwnv", "prnv", "deepwalk"), default="rwnv")
    ap.add_argument(
        "--engine",
        action="append",
        default=None,
        choices=("biblock", "pb", "sogw", "sgsc", "oracle"),
    )
    ap.add_argument("--vertices", type=int, default=5000)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=8)
    ap.add_argument("--walks-per-vertex", type=int, default=2)
    ap.add_argument("--length", type=int, default=20)
    ap.add_argument("--p", type=float, default=1.0)
    ap.add_argument("--q", type=float, default=1.0)
    ap.add_argument("--query", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loading", default="auto", choices=("auto", "full", "ondemand"))
    ap.add_argument(
        "--pool",
        default="memory",
        choices=("memory", "disk"),
        help="walk-pool backend (repro.io)",
    )
    ap.add_argument(
        "--pool-flush-walks",
        type=int,
        default=1 << 18,
        help="walk-pool spill threshold",
    )
    ap.add_argument(
        "--no-prefetch",
        action="store_true",
        help="disable BlockStore background prefetch",
    )
    ap.add_argument(
        "--no-async-pipeline",
        action="store_true",
        help="run the bi-block engine in the serial reference mode: no "
        "walk-pool writer thread, no next-slot preloads (bit-identical "
        "results, every pool load on the critical path)",
    )
    ap.add_argument(
        "--writer-queue",
        type=int,
        default=64,
        help="bounded depth of the async walk-pool writer queue "
        "(bi-block engine; ignored with --no-async-pipeline)",
    )
    ap.add_argument(
        "--pool-shards",
        type=int,
        default=1,
        help="partition the walk-pool keyspace across this many shards, "
        "each with its own sequenced writer thread (bi-block engine; "
        "requires the async pipeline; walks are bit-identical across "
        "shard counts)",
    )
    ap.add_argument(
        "--advance",
        default="jax",
        choices=("jax", "pallas"),
        help="UpdateWalk lowering: the plain jitted JAX advance or the "
        "fused Pallas multi-hop kernel (repro.kernels.pair_advance; "
        "interpret mode off-TPU) — walks are bit-identical either way",
    )
    ap.add_argument(
        "--graph-backend",
        default="ram",
        choices=("ram", "disk"),
        help="where graph blocks live: host RAM or the packed "
        "on-disk container (repro.io.blockfile)",
    )
    ap.add_argument(
        "--graph-dir",
        default=None,
        help="directory for the packed block file "
        "(disk backend; default: a fresh temp dir)",
    )
    ap.add_argument(
        "--io-coalesce-gap",
        type=int,
        default=0,
        help="waste budget (bytes) of the gap-aware on-demand read planner "
        "(repro.io.ioplan): holes up to this size are read through instead "
        "of seeked over; 0 = planner off, per-vertex reference reads",
    )
    args = ap.parse_args()

    from repro.core import (
        BiBlockEngine,
        InMemoryWalker,
        PlainBucketEngine,
        SOGWEngine,
        deepwalk_task,
        erdos_renyi,
        partition_into_n_blocks,
        prnv_task,
        rwnv_task,
    )

    g = erdos_renyi(args.vertices, args.vertices * args.avg_degree // 2, seed=args.seed)
    bg_ram = partition_into_n_blocks(g, args.blocks)
    if args.graph_backend == "disk":
        from repro.io import write_and_open

        # default scratch dir is removed at exit; an explicit --graph-dir
        # persists so the container can be reused across runs
        bg = write_and_open(bg_ram, args.graph_dir, io_coalesce_gap=args.io_coalesce_gap)
    else:
        bg = bg_ram
        bg.io_coalesce_gap = args.io_coalesce_gap
    if args.task == "rwnv":
        task = rwnv_task(
            p=args.p,
            q=args.q,
            walks_per_vertex=args.walks_per_vertex,
            length=args.length,
            seed=args.seed,
        )
    elif args.task == "prnv":
        task = prnv_task(args.query, g.num_vertices, p=args.p, q=args.q, seed=args.seed)
    else:
        task = deepwalk_task(
            walks_per_vertex=args.walks_per_vertex, length=args.length, seed=args.seed
        )

    pool_kw = dict(
        pool=args.pool,
        pool_flush_walks=args.pool_flush_walks,
        prefetch=not args.no_prefetch,
        advance_impl=args.advance,
    )
    biblock_kw = dict(
        pool_kw,
        loading=args.loading,
        async_pipeline=not args.no_async_pipeline,
        writer_queue=args.writer_queue,
        pool_shards=args.pool_shards,
    )
    engines = args.engine or ["biblock", "sogw"]
    print(
        "engine,block_ios,vertex_ios,ondemand_ios,ondemand_syscalls,"
        "coalesced_ranges,coalesce_waste_bytes,walk_bytes_written,"
        "peak_resident_bytes,prefetch_hits,overlapped_load_bytes,"
        "pipeline_stall_slots,writer_queue_peak,sim_io_s,exec_s,sim_wall_s"
    )
    for name in engines:
        if name == "biblock":
            res = BiBlockEngine(bg, task, **biblock_kw).run()
        elif name == "pb":
            res = PlainBucketEngine(bg, task, **pool_kw).run()
        elif name == "sogw":
            res = SOGWEngine(bg, task, **pool_kw).run()
        elif name == "sgsc":
            res = SOGWEngine(bg, task, static_cache=True, **pool_kw).run()
        else:
            # the oracle needs the whole CSR in RAM regardless of backend
            res = InMemoryWalker(bg_ram, task).run(record_walks=False)
        s = res.stats
        hits = (res.block_store_counters or {}).get("prefetch_hits", 0)
        print(
            f"{name},{s.block_ios},{s.vertex_ios},{s.ondemand_ios},"
            f"{s.ondemand_syscalls},{s.coalesced_ranges},{s.coalesce_waste_bytes},"
            f"{s.walk_bytes_written},{s.peak_resident_bytes},{hits},"
            f"{s.overlapped_load_bytes},{s.pipeline_stall_slots},"
            f"{s.writer_queue_peak},"
            f"{s.sim_io_time:.4f},{s.exec_time:.4f},{s.sim_wall_time:.4f}"
        )


if __name__ == "__main__":
    main()
