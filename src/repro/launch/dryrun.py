"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**input_specs()).compile()`` must succeed on
the (16,16) single-pod mesh AND the (2,16,16) multi-pod mesh for every
assigned architecture and input shape.  Nothing is ever allocated — all
inputs are ShapeDtypeStructs.

Outputs per cell: memory_analysis (bytes/device — proves it fits),
cost_analysis (FLOPs/bytes), the collective mix parsed from the optimized
HLO, and the raw artifacts benchmarks/roofline.py consumes.  Results are
cached incrementally in dryrun_results/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-cell]
"""

# The VERY FIRST lines — before any other import, jax locks the device count
# on first init:
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.models import init_params_shape, model_caches  # noqa: E402
from repro.models.common import ModelConfig  # noqa: E402
from repro.optim import OptConfig  # noqa: E402
from repro.sharding import batch_specs, cache_specs, named, param_specs  # noqa: E402
from repro.sharding.context import activation_rules, default_rules  # noqa: E402
from repro.train import make_decode_step, make_prefill_step, make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "dryrun_results"


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the token batch (+ stub frontend embeddings);
    decode: one new token + the full-length caches.
    """
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if spec.kind in ("train", "prefill"):
        P = cfg.num_prefix if cfg.frontend == "vision" else 0
        batch = {
            "tokens": sds((B, S - P), i32),
            "labels": sds((B, S - P), i32),
        }
        if cfg.frontend == "vision":
            batch["prefix"] = sds((B, P, cfg.d_model), cfg.dtype)
        if cfg.is_encoder_decoder:
            batch["frames"] = sds((B, S, cfg.d_model), cfg.dtype)
        if spec.kind == "prefill":
            batch.pop("labels")
        return {"batch": batch}
    # decode: one token against a seq_len cache
    caches = jax.eval_shape(
        lambda: model_caches(cfg, B, S, enc_len=S)
    )
    return {
        "batch": {"token": sds((B, 1), i32), "cache_len": sds((), i32)},
        "caches": caches,
    }


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def _collective_mix(hlo_text: str) -> dict:
    counts = {}
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"):
        counts[op] = sum(
            1
            for ln in hlo_text.splitlines()
            if f" {op}" in ln or ln.lstrip().startswith(f"%{op}")
            or f"= {op}(" in ln or f" {op}(" in ln
        )
    return counts


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    save_hlo: bool = True,
    microbatches: int = 1,
    donate: bool = True,
):
    """Lower + compile one cell. Returns the result record (dict)."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.shape),
        "devices": n_dev,
        "kind": spec.kind,
        "ok": False,
    }

    params_shape = init_params_shape(cfg)
    pmode = "train" if spec.kind == "train" else "serve"
    pspecs = param_specs(cfg, params_shape, mesh, mode=pmode)
    pshard = named(mesh, pspecs)
    params_abs = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shape, pshard,
    )
    ins = input_specs(cfg, shape_name)
    bspecs = batch_specs(cfg, mesh, spec.global_batch, kind=spec.kind)
    bshard = named(mesh, {k: bspecs[k] for k in ins["batch"]})
    batch_abs = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        ins["batch"], bshard,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    t0 = time.time()
    act_rules = default_rules(mesh, spec.global_batch, spec.seq_len, cfg.d_model)
    with jax.set_mesh(mesh), activation_rules(act_rules):
        if spec.kind == "train":
            mb = max(microbatches, cfg.train_microbatches)
            record["microbatches"] = mb
            step = make_train_step(cfg, OptConfig(), microbatches=mb)
            # abstract optimizer state (fp32 twins of params, same sharding)
            from repro.optim import AdamWState

            f32 = lambda l, s: jax.ShapeDtypeStruct(l.shape, jnp.float32, sharding=s)
            opt_abs = AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                master=jax.tree.map(f32, params_shape, pshard),
                m=jax.tree.map(f32, params_shape, pshard),
                v=jax.tree.map(f32, params_shape, pshard),
            )
            jitted = jax.jit(
                step, donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif spec.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step)
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            step = make_decode_step(cfg)
            cspecs = cache_specs(cfg, ins["caches"], mesh, spec.global_batch)
            cshard = named(mesh, cspecs)
            caches_abs = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                ins["caches"], cshard,
            )
            jitted = jax.jit(step, donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_abs, batch_abs, caches_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    record.update(
        ok=True,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        bytes_per_device={
            "arguments": getattr(mem, "argument_size_in_bytes", None),
            "outputs": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "alias": getattr(mem, "alias_size_in_bytes", None),
        },
        cost_analysis={
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        collectives=_collective_mix(hlo),
        params=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    if save_hlo:
        RESULTS_DIR.mkdir(exist_ok=True)
        tag = f"{arch}_{shape_name}_{record['mesh']}"
        (RESULTS_DIR / f"{tag}.hlo").write_text(hlo)
        record["hlo_path"] = str(RESULTS_DIR / f"{tag}.hlo")
    return record


def cell_id(arch, shape, multi_pod):
    return f"{arch}|{shape}|{'2pod' if multi_pod else '1pod'}"


def run_all(*, multi_pod_values=(False, True), archs=None, shapes=None,
            force: bool = False):
    RESULTS_DIR.mkdir(exist_ok=True)
    summary_path = RESULTS_DIR / "summary.json"
    summary = {}
    if summary_path.exists():
        summary = json.loads(summary_path.read_text())
    archs = archs or ARCH_IDS
    shapes = shapes or list(SHAPES)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not shape_applicable(cfg, shape):
                summary[cell_id(arch, shape, False)] = {
                    "arch": arch, "shape": shape, "skipped": True,
                    "reason": "inapplicable (see DESIGN.md §4.1)",
                }
                summary_path.write_text(json.dumps(summary, indent=1))
                continue
            for mp in multi_pod_values:
                cid = cell_id(arch, shape, mp)
                if not force and summary.get(cid, {}).get("ok"):
                    continue
                print(f"=== {cid} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=mp)
                    print(
                        f"    ok: compile {rec['compile_s']}s, "
                        f"temp/dev {rec['bytes_per_device']['temp']}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {
                        "arch": arch, "shape": shape, "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(f"    FAIL: {rec['error'][:200]}", flush=True)
                summary[cid] = rec
                summary_path.write_text(json.dumps(summary, indent=1))
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.all:
        summary = run_all(force=args.force)
        n_ok = sum(1 for v in summary.values() if v.get("ok"))
        n_skip = sum(1 for v in summary.values() if v.get("skipped"))
        n_fail = sum(
            1 for v in summary.values() if not v.get("ok") and not v.get("skipped")
        )
        print(f"\ncells ok={n_ok} skipped={n_skip} failed={n_fail}")
        raise SystemExit(1 if n_fail else 0)
    rec = run_cell(
        args.arch, args.shape, multi_pod=args.multi_pod
    )
    print(json.dumps({k: v for k, v in rec.items() if k != "trace"}, indent=2))


if __name__ == "__main__":
    main()
