"""Query-serving launcher: drive a skewed point-query mix from the CLI.

    PYTHONPATH=src python -m repro.launch.serve --vertices 3000 --blocks 10 \
        --queries 96 --samples 32 --max-batch 32 [--hot-blocks 2] \
        [--skew 0.85] [--p 4 --q 0.25] [--length 20] [--decay 0.85] \
        [--pool disk] [--graph-backend disk --graph-dir DIR] \
        [--no-async-pipeline] [--advance pallas] [--seed 0]

Builds a Barabási–Albert graph, submits ``--queries`` point queries whose
sources concentrate on the hottest block with probability ``--skew``
(uniform otherwise), serves them through :class:`repro.serve
.WalkQueryServer` in admission batches of ``--max-batch``, and prints the
per-query latency percentiles plus the hot-set pinning ledger
(``pinned_block_hits`` / ``pinned_bytes_saved`` vs total ``block_load``
charges).  ``--hot-blocks 0`` is the pure-LRU reference.
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=3000)
    ap.add_argument("--avg-degree", type=int, default=8)
    ap.add_argument("--blocks", type=int, default=10)
    ap.add_argument("--queries", type=int, default=96, help="point queries to submit")
    ap.add_argument("--samples", type=int, default=32, help="walks per query")
    ap.add_argument(
        "--max-batch",
        type=int,
        default=32,
        help="admission batch size: the latency/throughput dial "
        "(larger batches amortize block loads better but hold "
        "early arrivals longer)",
    )
    ap.add_argument(
        "--hot-blocks",
        type=int,
        default=2,
        help="blocks the hot-set policy may pin resident "
        "(0 disables pinning: the pure-LRU reference)",
    )
    ap.add_argument(
        "--skew",
        type=float,
        default=0.85,
        help="fraction of query sources drawn from the highest-degree "
        "block (the rest are uniform over all vertices)",
    )
    ap.add_argument("--p", type=float, default=1.0)
    ap.add_argument("--q", type=float, default=1.0)
    ap.add_argument("--length", type=int, default=20)
    ap.add_argument("--decay", type=float, default=0.85)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--block-cache",
        type=int,
        default=4,
        help="LRU capacity (blocks) of the server's shared BlockStore",
    )
    ap.add_argument(
        "--pool",
        default="memory",
        choices=("memory", "disk"),
        help="walk-pool backend (repro.io)",
    )
    ap.add_argument(
        "--no-async-pipeline",
        action="store_true",
        help="serve each batch in the serial reference mode",
    )
    ap.add_argument(
        "--advance",
        default="jax",
        choices=("jax", "pallas"),
        help="UpdateWalk lowering (see repro.launch.walk)",
    )
    ap.add_argument(
        "--graph-backend",
        default="ram",
        choices=("ram", "disk"),
        help="where graph blocks live: host RAM or the packed "
        "on-disk container (repro.io.blockfile)",
    )
    ap.add_argument(
        "--graph-dir",
        default=None,
        help="directory for the packed block file (disk backend)",
    )
    ap.add_argument(
        "--io-coalesce-gap",
        type=int,
        default=0,
        help="waste budget (bytes) of the gap-aware on-demand read planner "
        "(repro.io.ioplan); 0 = planner off, per-vertex reference reads",
    )
    args = ap.parse_args()

    from repro.core import barabasi_albert, partition_into_n_blocks
    from repro.serve import QueryConfig, WalkQueryServer

    g = barabasi_albert(args.vertices, max(args.avg_degree // 2, 1), seed=args.seed + 2)
    bg = partition_into_n_blocks(g, args.blocks)
    if args.graph_backend == "disk":
        from repro.io import write_and_open

        bg = write_and_open(bg, args.graph_dir, io_coalesce_gap=args.io_coalesce_gap)
    else:
        bg.io_coalesce_gap = args.io_coalesce_gap

    config = QueryConfig(
        p=args.p, q=args.q, length=args.length, decay=args.decay, samples=args.samples
    )
    # BA preferential attachment puts the hubs at the low vertex ids, so
    # block 0 is the natural hot block for the skewed mix
    rng = np.random.default_rng(args.seed + 7)
    hot_lo, hot_hi = int(bg.block_starts[0]), int(bg.block_starts[1])
    with WalkQueryServer(
        bg,
        max_batch=args.max_batch,
        hot_blocks=args.hot_blocks,
        block_cache_blocks=args.block_cache,
        seed=args.seed,
        pool=args.pool,
        async_pipeline=not args.no_async_pipeline,
        advance_impl=args.advance,
    ) as server:
        for _ in range(args.queries):
            if rng.random() < args.skew:
                source = int(rng.integers(hot_lo, hot_hi))
            else:
                source = int(rng.integers(0, bg.num_vertices))
            server.submit(source, config)
        answers = server.flush()
        lat = server.latency_summary()
        s = server.stats
        print(
            "queries,batches,p50_ms,p95_ms,p99_ms,block_ios,pinned_blocks,"
            "pinned_hits,pinned_bytes_saved,ondemand_syscalls,"
            "coalesced_ranges,coalesce_waste_bytes"
        )
        print(
            f"{len(answers)},{server.batches_served},"
            f"{lat['p50'] * 1e3:.2f},{lat['p95'] * 1e3:.2f},{lat['p99'] * 1e3:.2f},"
            f"{s.block_ios},{s.hot_pinned_blocks},{s.pinned_block_hits},"
            f"{s.pinned_bytes_saved},{s.ondemand_syscalls},"
            f"{s.coalesced_ranges},{s.coalesce_waste_bytes}"
        )


if __name__ == "__main__":
    main()
