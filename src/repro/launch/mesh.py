"""Production meshes.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state): (16, 16) = one v5e pod, 256 chips, axes (data, model);
multi_pod adds a leading "pod" axis — (2, 16, 16) = 512 chips.  The caller
is responsible for the device pool (real TPUs, or
``--xla_force_host_platform_device_count=512`` in the dry-run).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
