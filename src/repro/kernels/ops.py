"""jit'd public wrappers for the walk-step kernels.

``node2vec_step`` pads the walk batch to the tile size, draws the uniforms,
dispatches either the Pallas kernel (TPU / interpret) or the pure-jnp
reference, and unpads.  The engines call this one entry point; tests sweep
both paths and assert they agree.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .node2vec_ref import node2vec_step_ref
from .node2vec_step import WALK_TILE, node2vec_step_kernel

__all__ = ["node2vec_step", "alias_step"]


@partial(
    jax.jit,
    static_argnames=(
        "p", "q", "order", "k_max", "n_iters", "has_alias", "use_kernel",
        "interpret", "walk_tile",
    ),
)
def node2vec_step(
    pair_start,
    pair_nverts,
    indptr,
    indices,
    alias_j,
    alias_q,
    prev,
    cur,
    hop,
    active,
    key,
    *,
    p: float = 1.0,
    q: float = 1.0,
    order: int = 2,
    k_max: int = 4,
    n_iters: int = 24,
    has_alias: bool = False,
    use_kernel: bool = True,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """One walk step for a batch over a resident pair. Returns (z, moved)."""
    n = prev.shape[0]
    pad = (-n) % walk_tile
    if pad:
        pad32 = lambda x: jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        prev, cur, hop = pad32(prev), pad32(cur), pad32(hop)
        active = jnp.concatenate([active, jnp.zeros((pad,), bool)])
    N = prev.shape[0]
    unif = jax.random.uniform(key, (N, k_max, 3))
    fn = node2vec_step_kernel if use_kernel else node2vec_step_ref
    kw = dict(
        p=p, q=q, order=order, k_max=k_max, n_iters=n_iters, has_alias=has_alias
    )
    if use_kernel:
        kw.update(interpret=interpret, walk_tile=walk_tile)
    z, moved = fn(
        pair_start, pair_nverts, indptr, indices, alias_j, alias_q,
        prev, cur, hop, active, unif, **kw,
    )
    return z[:n], moved[:n]


@partial(
    jax.jit,
    static_argnames=("has_alias", "use_kernel", "interpret", "walk_tile"),
)
def alias_step(
    pair_start,
    pair_nverts,
    indptr,
    indices,
    alias_j,
    alias_q,
    cur,
    active,
    key,
    *,
    has_alias: bool = True,
    use_kernel: bool = True,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """First-order (DeepWalk) step: alias/uniform neighbor draw."""
    zero = jnp.zeros_like(cur)
    return node2vec_step(
        pair_start, pair_nverts, indptr, indices, alias_j, alias_q,
        zero, cur, zero, active, key,
        p=1.0, q=1.0, order=1, k_max=1, n_iters=1, has_alias=has_alias,
        use_kernel=use_kernel, interpret=interpret, walk_tile=walk_tile,
    )
