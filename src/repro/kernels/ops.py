"""jit'd public wrappers for the walk-step kernels (view-pair layout).

``node2vec_step`` is the single-hop form of the fused advance: with
``use_kernel=True`` it runs :func:`repro.kernels.pair_advance
.fused_advance_pair` capped at one hop (``max_hops=1``, termination
disabled); with ``use_kernel=False`` it draws the same counter-keyed
uniforms through :mod:`repro.kernels.rng` on the host and feeds the
independent dense oracle :func:`repro.kernels.node2vec_ref
.node2vec_step_ref`.  The two paths agree bit for bit — that equality is
what validates the kernel's internal RNG and sampling logic, and tests
sweep both.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import rng
from .node2vec_ref import node2vec_step_ref
from .pair_advance import WALK_TILE, fused_advance_pair

__all__ = ["node2vec_step", "alias_step"]


@partial(
    jax.jit,
    static_argnames=(
        "p",
        "q",
        "order",
        "k_max",
        "n_iters",
        "v_iters",
        "has_alias",
        "use_kernel",
        "interpret",
        "walk_tile",
    ),
)
def node2vec_step(
    vids,
    nverts,
    vid_base,
    indptr,
    ptr_base,
    indices,
    ind_base,
    alias_j,
    alias_q,
    wid,
    prev,
    cur,
    hop,
    active,
    key,
    *,
    p: float = 1.0,
    q: float = 1.0,
    order: int = 2,
    k_max: int = 4,
    n_iters: int = 24,
    v_iters: int = 12,
    has_alias: bool = False,
    use_kernel: bool = True,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """One walk hop for a batch over a resident pair. Returns (z, moved)."""
    if use_kernel:
        _, cur_f, hop_f, _, _, _ = fused_advance_pair(
            vids,
            nverts,
            vid_base,
            indptr,
            ptr_base,
            indices,
            ind_base,
            alias_j,
            alias_q,
            wid,
            prev,
            cur,
            hop,
            active,
            key,
            jnp.int32(jnp.iinfo(jnp.int32).max),  # never length-finished
            jnp.float32(1.0),  # never decay-stopped
            jnp.float32(p),
            jnp.float32(q),
            order=order,
            k_max=k_max,
            n_iters=n_iters,
            v_iters=v_iters,
            record=False,
            has_alias=has_alias,
            max_len=1,
            max_hops=1,
            interpret=interpret,
            walk_tile=walk_tile,
        )
        return cur_f, hop_f - hop
    # reference path: materialize the counter-keyed draws explicitly —
    # (base_key, walk_id, hop, round), exactly the kernel's fold chain
    kw0, kw1 = rng.fold_in(*rng.fold_in(*rng.key_halves(key), wid), hop)
    unif = jnp.stack(
        [jnp.stack(rng.uniform3(*rng.fold_in(kw0, kw1, kk)), axis=-1) for kk in range(k_max)],
        axis=1,
    )
    return node2vec_step_ref(
        vids,
        nverts,
        vid_base,
        indptr,
        ptr_base,
        indices,
        ind_base,
        alias_j,
        alias_q,
        prev,
        cur,
        hop,
        active,
        unif,
        p=p,
        q=q,
        order=order,
        k_max=k_max,
        has_alias=has_alias,
    )


@partial(
    jax.jit,
    static_argnames=("v_iters", "has_alias", "use_kernel", "interpret", "walk_tile"),
)
def alias_step(
    vids,
    nverts,
    vid_base,
    indptr,
    ptr_base,
    indices,
    ind_base,
    alias_j,
    alias_q,
    wid,
    cur,
    active,
    key,
    *,
    v_iters: int = 12,
    has_alias: bool = True,
    use_kernel: bool = True,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """First-order (DeepWalk) hop: alias/uniform neighbor draw."""
    zero = jnp.zeros_like(cur)
    return node2vec_step(
        vids,
        nverts,
        vid_base,
        indptr,
        ptr_base,
        indices,
        ind_base,
        alias_j,
        alias_q,
        wid,
        zero,
        cur,
        zero,
        active,
        key,
        p=1.0,
        q=1.0,
        order=1,
        k_max=1,
        n_iters=1,
        v_iters=v_iters,
        has_alias=has_alias,
        use_kernel=use_kernel,
        interpret=interpret,
        walk_tile=walk_tile,
    )
