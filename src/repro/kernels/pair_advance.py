"""Fused Pallas multi-hop advance over the packed ragged view pair.

This is Alg. 2's ``UpdateWalk`` loop — the compute hot-spot of the bi-block
engine — as **one** kernel invocation instead of a chain of XLA ops: the
vids-remap binary search, alias/uniform proposal, second-order rejection
with binary-search membership, termination/decay draw, and trace-record
packing all execute per walker tile with the view pair pinned in VMEM.

Layout is the :class:`~repro.engines.base.ResidentPair` packing — flat
ragged ``vids``/``indptr``/``indices`` segments plus per-slot base offsets
— *not* the contiguous ``(start, nverts)`` block pair the retired
single-step kernel assumed, so compacted on-demand views run as-is.

ThunderRW's step interleaving maps onto the grid: the walk batch streams
through in ``WALK_TILE`` chunks (grid dim 0) and each tile runs its *own*
multi-hop ``while_loop``, masking per lane.  A lane that leaves the pair or
terminates stops contributing (its ``resident`` bit drops) without
serializing the lanes still walking; a tile whose lanes have all stalled
exits its loop immediately.  Per grid step the VMEM working set is

    (SV + SP + SE) * 4 bytes          (vids + indptr + indices)
  + 2 * SE * 4 (+ SE * 4)             (alias tables when weighted)
  + WALK_TILE * (7 * 4 + trace cols)  (walker lanes + trace tile)

which for the default ``WALK_TILE = 512`` leaves the paper's "block size"
knob (ME ~ 400-500 K edges on a 16 MB VMEM part) intact.

Every draw goes through :mod:`repro.kernels.rng` — the hand-rolled
threefry2x32 keyed ``(base_key, walk_id, hop, round)`` — so the fused path
reproduces :func:`repro.engines.step.pair_advance_impl` (and therefore the
in-memory oracle) bit for bit; ``advance_impl={"jax","pallas"}`` in
:class:`repro.engines.base.EngineBase` switches between them.

``interpret=True`` (the default, and what CPU CI exercises) runs the same
kernel body under the Pallas interpreter; on TPU pass ``interpret=False``
to lower through Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rng

__all__ = ["WALK_TILE", "fused_advance_pair", "pair_advance_kernel"]

#: walker lanes per grid step
WALK_TILE = 512


def _lower_bound(flat, lo, hi, z, *, n_iters: int):
    """Kernel twin of :func:`repro.engines.step.lower_bound_rows`: fixed
    ``n_iters``-halving lower bound of ``z`` in sorted ``flat[lo:hi]``."""

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        val = flat[jnp.clip(mid, 0, flat.shape[0] - 1)]
        valid = lo_ < hi_
        go_right = valid & (val < z)
        lo_ = jnp.where(go_right, mid + 1, lo_)
        hi_ = jnp.where(valid & ~go_right, mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    pos = jnp.clip(lo_f, 0, flat.shape[0] - 1)
    return lo_f, (lo_f < hi) & (flat[pos] == z)


def pair_advance_kernel(
    vids_ref,      # [SV] i32   VMEM, whole pair resident
    nverts_ref,    # [2] i32    scalars (VMEM for interpret, SMEM-ish)
    vid_base_ref,  # [2] i32
    ptr_base_ref,  # [2] i32
    ind_base_ref,  # [2] i32
    indptr_ref,    # [SP] i32
    indices_ref,   # [SE] i32
    alias_j_ref,   # [SE] i32 ([1] dummy if not has_alias)
    alias_q_ref,   # [SE] f32
    key_ref,       # [2] u32    the task base key's raw halves
    ilen_ref,      # [1] i32    walk length in edges
    fpar_ref,      # [3] f32    (decay, p, q)
    wid_ref,       # [T] i32    walker tile (grid dim 0)
    prev_ref,      # [T] i32
    cur_ref,       # [T] i32
    hop_ref,       # [T] i32
    alive_ref,     # [T] i32
    prev_out,      # [T] i32
    cur_out,       # [T] i32
    hop_out,       # [T] i32
    alive_out,     # [T] i32
    trace_out,     # [T, max_len+2] i32 ([T, 1] if not record)
    *,
    order: int,
    k_max: int,
    n_iters: int,
    v_iters: int,
    record: bool,
    has_alias: bool,
    max_len: int,
    max_hops: int,
):
    T = prev_ref.shape[0]
    vids = vids_ref[...]
    indptr = indptr_ref[...]
    indices = indices_ref[...]
    vb0, vb1 = vid_base_ref[0], vid_base_ref[1]
    nv0, nv1 = nverts_ref[0], nverts_ref[1]
    pb0, pb1 = ptr_base_ref[0], ptr_base_ref[1]
    ib0, ib1 = ind_base_ref[0], ind_base_ref[1]
    length = ilen_ref[0]
    decay, p, q = fpar_ref[0], fpar_ref[1], fpar_ref[2]
    max_bias = jnp.maximum(1.0, jnp.maximum(1.0 / p, 1.0 / q))

    wid = wid_ref[...]
    prev0 = prev_ref[...]
    cur0 = cur_ref[...]
    hop0 = hop_ref[...]
    alive0 = alive_ref[...] > 0
    # per-walk streams, hoisted: the hop/round folds happen inside the loop
    kwid = rng.fold_in(key_ref[0], key_ref[1], wid)
    trace0 = jnp.full(trace_out.shape, -1, jnp.int32)

    def locate(v):
        r0, found0 = _lower_bound(
            vids, jnp.full((T,), vb0), jnp.full((T,), vb0 + nv0), v, n_iters=v_iters
        )
        r1, found1 = _lower_bound(
            vids, jnp.full((T,), vb1), jnp.full((T,), vb1 + nv1), v, n_iters=v_iters
        )
        slot = jnp.where(found0, 0, 1).astype(jnp.int32)
        row = jnp.where(found0, r0 - vb0, r1 - vb1)
        row = jnp.maximum(row, 0)
        return slot, row, found0 | found1

    def cond(state):
        _, _, _, _, resident, _, _, _, it = state
        return jnp.any(resident) & (it < max_hops)

    def body(state):
        prev_, cur_, hop_, alive_, resident, slot, row, trace_, it = state
        kw0, kw1 = rng.fold_in(kwid[0], kwid[1], hop_)

        movable = resident
        pslot = jnp.where(slot == 0, pb0, pb1)
        row_start = indptr[pslot + row]
        deg = indptr[pslot + row + 1] - row_start
        dead = movable & (deg <= 0)
        movable = movable & (deg > 0)
        deg_c = jnp.maximum(deg, 1)
        islot = jnp.where(slot == 0, ib0, ib1)

        if order == 2:
            uslot, urow, _ = locate(prev_)
            pu = jnp.where(uslot == 0, pb0, pb1)
            u_start = indptr[pu + urow]
            ulo = jnp.where(uslot == 0, ib0, ib1) + u_start
            uhi = ulo + (indptr[pu + urow + 1] - u_start)

        # ---- proposal + rejection, k_max rounds unrolled --------------------
        z = cur_
        accepted = ~movable
        for kk in range(k_max):
            u1, u2, u3 = rng.uniform3(*rng.fold_in(kw0, kw1, kk))
            kloc = jnp.minimum((u1 * deg_c).astype(jnp.int32), deg_c - 1)
            idx = islot + row_start + kloc
            if has_alias:
                take_alias = u2 >= alias_q_ref[...][idx]
                kloc = jnp.where(take_alias, alias_j_ref[...][idx], kloc)
                idx = islot + row_start + kloc
            zk = indices[idx]
            if order == 2:
                _, memb = _lower_bound(indices, ulo, uhi, zk, n_iters=n_iters)
                bias = jnp.where(zk == prev_, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
                acc_p = bias / max_bias
                acc_p = jnp.where(hop_ == 0, 1.0, acc_p)  # first step: 1st-order
            else:
                acc_p = jnp.ones((T,), jnp.float32)
            last = kk == k_max - 1
            take = (~accepted) & movable & ((u3 < acc_p) | last)
            z = jnp.where(take, zk, z)
            accepted = accepted | take

        # ---- commit ---------------------------------------------------------
        u_term = rng.uniform1(*rng.fold_in(kw0, kw1, k_max))
        new_hop = hop_ + movable.astype(jnp.int32)
        new_prev = jnp.where(movable, cur_, prev_)
        new_cur = jnp.where(movable, z, cur_)
        finished = movable & (new_hop >= length)
        stopped = movable & (u_term >= decay)
        new_alive = alive_ & ~dead & ~finished & ~stopped
        new_slot, new_row, new_found = locate(new_cur)
        new_resident = new_alive & new_found
        if record:
            # one-hot column select — the Mosaic-friendly spelling of the
            # impl's scatter trace_.at[iota, cols].set(new_cur); the dump
            # column max_len+1 absorbs writes of frozen lanes
            cols = jnp.where(movable, jnp.clip(new_hop, 0, max_len), max_len + 1)
            onehot = jax.lax.broadcasted_iota(jnp.int32, trace_.shape, 1) == cols[:, None]
            trace_ = jnp.where(onehot, new_cur[:, None], trace_)
        return (
            new_prev,
            new_cur,
            new_hop,
            new_alive,
            new_resident,
            new_slot,
            new_row,
            trace_,
            it + 1,
        )

    slot0, row0, found0 = locate(cur0)
    resident0 = alive0 & found0
    init = (prev0, cur0, hop0, alive0, resident0, slot0, row0, trace0, jnp.int32(0))
    prev_f, cur_f, hop_f, alive_f, _, _, _, trace_f, _ = jax.lax.while_loop(cond, body, init)

    prev_out[...] = prev_f
    cur_out[...] = cur_f
    hop_out[...] = hop_f
    alive_out[...] = alive_f.astype(jnp.int32)
    trace_out[...] = trace_f


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "k_max",
        "n_iters",
        "v_iters",
        "record",
        "has_alias",
        "max_len",
        "max_hops",
        "interpret",
        "walk_tile",
    ),
)
def fused_advance_pair(
    vids,
    nverts,
    vid_base,
    indptr,
    ptr_base,
    indices,
    ind_base,
    alias_j,
    alias_q,
    wid,
    prev,
    cur,
    hop,
    alive,
    key,
    length,
    decay,
    p,
    q,
    *,
    order: int,
    k_max: int,
    n_iters: int,
    v_iters: int,
    record: bool,
    has_alias: bool,
    max_len: int,
    max_hops: int | None = None,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """Drop-in fused replacement for :func:`repro.engines.step.advance_pair`.

    Identical argument list and return contract
    ``(prev, cur, hop, alive, steps, trace)``; bit-identical outputs.  The
    extra statics select the Pallas lowering: ``interpret`` (CI-safe CPU
    interpreter vs Mosaic TPU), ``walk_tile`` (grid chunk), and
    ``max_hops`` (loop bound — ``None`` means the full ``max_len + 1``
    sweep; 1 gives the single-step form :mod:`repro.kernels.ops` exposes).
    """
    n0 = prev.shape[0]
    tile = min(walk_tile, n0)
    pad = (-n0) % tile

    def pad_lane(x, fill):
        return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)]) if pad else x

    wid = pad_lane(wid, 0)
    prev = pad_lane(prev, 0)
    cur = pad_lane(cur, 0)
    hop_in = pad_lane(hop, 0)
    alive_i = pad_lane(alive.astype(jnp.int32), 0)
    N = prev.shape[0]
    grid = (N // tile,)
    hops = (max_len + 1) if max_hops is None else max_hops
    TC = (max_len + 2) if record else 1

    k0, k1 = rng.key_halves(key)
    keypair = jnp.stack([k0, k1]).astype(jnp.uint32)
    ilen = jnp.asarray(length, jnp.int32).reshape(1)
    fpar = jnp.stack([decay, p, q]).astype(jnp.float32)

    pair_spec = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    walk_spec = pl.BlockSpec((tile,), lambda i: (i,))
    trace_spec = pl.BlockSpec((tile, TC), lambda i: (i, 0))

    kern = functools.partial(
        pair_advance_kernel,
        order=order,
        k_max=k_max,
        n_iters=n_iters,
        v_iters=v_iters,
        record=record,
        has_alias=has_alias,
        max_len=max_len,
        max_hops=hops,
    )
    prev_f, cur_f, hop_f, alive_f, trace = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pair_spec(vids.shape),
            pair_spec((2,)),
            pair_spec((2,)),
            pair_spec((2,)),
            pair_spec((2,)),
            pair_spec(indptr.shape),
            pair_spec(indices.shape),
            pair_spec(alias_j.shape),
            pair_spec(alias_q.shape),
            pair_spec((2,)),
            pair_spec((1,)),
            pair_spec((3,)),
            walk_spec,
            walk_spec,
            walk_spec,
            walk_spec,
            walk_spec,
        ],
        out_specs=[walk_spec, walk_spec, walk_spec, walk_spec, trace_spec],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N, TC), jnp.int32),
        ],
        interpret=interpret,
    )(
        vids,
        nverts,
        vid_base,
        ptr_base,
        ind_base,
        indptr,
        indices,
        alias_j,
        alias_q,
        keypair,
        ilen,
        fpar,
        wid,
        prev,
        cur,
        hop_in,
        alive_i,
    )
    # hop only advances on committed moves, so the delta *is* the step count
    steps = jnp.sum(hop_f - hop_in).astype(jnp.int32)
    if record:
        trace = trace[:n0, : max_len + 1]
    else:
        trace = jnp.full((1, 1), -1, jnp.int32)
    return prev_f[:n0], cur_f[:n0], hop_f[:n0], alive_f[:n0] > 0, steps, trace
