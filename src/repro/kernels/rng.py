"""Hand-rolled threefry2x32 — the single source of every walk-step draw.

The engines key each random draw by ``(base_key, walk_id, hop, round)``
(see :mod:`repro.engines.step`), which upstream jax spells as nested
``jax.random.fold_in`` + ``jax.random.uniform``.  Those call into the
``threefry2x32`` *primitive*, whose CPU/TPU lowering Mosaic cannot ingest
inside a Pallas kernel body.  This module re-derives the same bits from
scratch with plain ``jnp`` elementwise ops — adds, xors, rotates — which
lower identically under jit, vmap, shard_map, and Mosaic.  Every function
here is **bitwise identical** to its ``jax.random`` counterpart (pinned by
``tests/test_rng.py``), so the fused Pallas advance kernel, the jitted JAX
impl, and the distributed sweep all draw the very same uniforms.

Keys are carried as a raw ``uint32`` pair ``(k0, k1)`` rather than jax key
arrays: Pallas refs are flat arrays, and the pair form broadcasts — fold a
scalar key against a ``[N]`` walk-id vector and every output is ``[N]``.

Bit-compat notes (jax 0.4.37, default non-partitionable threefry):

* ``fold_in(key, d)`` is ``threefry2x32(key, [0, uint32(d)])``.
* ``uniform(key, (3,))`` pads the odd count to 4 and evaluates the block
  cipher on counter halves ``x0=[0,1], x1=[2,0]``; the bits land as
  ``[T(0,2).out0, T(1,0).out0, T(0,2).out1]`` — two cipher calls, not
  three.  ``uniform(key, ())`` is ``T(0,0).out0``.
* bits -> float32 in [0,1): ``bitcast((bits >> 9) | 0x3F800000) - 1.0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["threefry2x32", "fold_in", "uniform1", "uniform3", "key_halves"]

#: threefry ks-parity constant (SHA-1 of "threefish", truncated)
_PARITY = 0x1BD11BDA
#: rotation distances — groups alternate between the two quadruples
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
#: key-injection schedule after each 4-round group: (into-x0, into-x1, tweak)
_INJECT = ((1, 2, 1), (2, 0, 2), (0, 1, 3), (1, 2, 4), (2, 0, 5))


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """The Threefry-2x32 block cipher (20 rounds), elementwise over arrays.

    All inputs broadcast against each other as ``uint32``; returns the two
    output words ``(y0, y1)``.  Matches ``jax.random.threefry_2x32`` bit for
    bit.
    """
    k0 = jnp.asarray(k0).astype(jnp.uint32)
    k1 = jnp.asarray(k1).astype(jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    y0 = jnp.asarray(x0).astype(jnp.uint32) + ks[0]
    y1 = jnp.asarray(x1).astype(jnp.uint32) + ks[1]
    for g, (ia, ib, tweak) in enumerate(_INJECT):
        for r in _ROTATIONS[g % 2]:
            y0 = y0 + y1
            y1 = _rotl(y1, r) ^ y0
        y0 = y0 + ks[ia]
        y1 = y1 + ks[ib] + jnp.uint32(tweak)
    return y0, y1


def fold_in(k0, k1, data):
    """``jax.random.fold_in`` on a raw key pair: returns the folded pair.

    ``data`` may be any int array/scalar (non-negative values reinterpret
    bit-exactly); broadcasting against the key pair is allowed.
    """
    zero = jnp.zeros((), jnp.uint32)
    return threefry2x32(k0, k1, zero, jnp.asarray(data).astype(jnp.uint32))


def _bits_to_unit(bits):
    """uint32 random bits -> float32 in [0, 1), jax.random.uniform's map."""
    mantissa = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    return jax.lax.bitcast_convert_type(mantissa, jnp.float32) - jnp.float32(1.0)


def uniform1(k0, k1):
    """``jax.random.uniform(key, ())`` for every key in the pair arrays."""
    b0, _ = threefry2x32(k0, k1, jnp.uint32(0), jnp.uint32(0))
    return _bits_to_unit(b0)


def uniform3(k0, k1):
    """``jax.random.uniform(key, (3,))`` per key: returns ``(u0, u1, u2)``.

    The odd draw count makes jax pad the counter block to 4, so the three
    values come out of two cipher evaluations in padded order.
    """
    a0, a1 = threefry2x32(k0, k1, jnp.uint32(0), jnp.uint32(2))
    b0, _ = threefry2x32(k0, k1, jnp.uint32(1), jnp.uint32(0))
    return _bits_to_unit(a0), _bits_to_unit(b0), _bits_to_unit(a1)


def key_halves(key):
    """Split a ``jax.random.PRNGKey`` (raw or typed) into ``(k0, k1)``."""
    kd = jnp.asarray(key)
    if kd.dtype != jnp.uint32:  # new-style typed key
        kd = jax.random.key_data(key)
    return kd[..., 0], kd[..., 1]
