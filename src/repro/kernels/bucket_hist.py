"""Pallas TPU kernel: bucket histogram for the in-memory walk manager.

The bucket-based walk management (§4.3.2) is a counting sort keyed by the
walk's bucket id.  The count pass is the TPU-hostile part (scatter-add);
the TPU-idiomatic formulation is a one-hot reduction, which the MXU does as
a [1, T] x [T, NB] matmul per walk tile.  The sort itself then becomes a
prefix-sum + gather in plain XLA.

Grid: one step per walk tile; every step accumulates into the same output
block (revisited output pattern — initialise at step 0, accumulate after).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bucket_hist_kernel", "bucket_hist_ref", "HIST_TILE"]

HIST_TILE = 1024


def _kernel(ids_ref, valid_ref, out_ref, *, num_buckets: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]
    valid = valid_ref[...] > 0
    # one-hot [T, NB] in f32; reduce over T on the MXU (ones-vector matmul)
    oh = (ids[:, None] == jnp.arange(num_buckets)[None, :]) & valid[:, None]
    ones = jnp.ones((1, ids.shape[0]), jnp.float32)
    counts = jnp.dot(ones, oh.astype(jnp.float32), preferred_element_type=jnp.float32)[0]
    out_ref[...] += counts.astype(jnp.int32)


def bucket_hist_kernel(
    ids, valid, *, num_buckets: int, interpret: bool = True, tile: int = HIST_TILE
):
    """Count walks per bucket. ``ids``: [N] int32; ``valid``: [N] bool."""
    N = ids.shape[0]
    if N % tile:
        raise ValueError(f"walk count {N} must be a multiple of {tile}")
    return pl.pallas_call(
        functools.partial(_kernel, num_buckets=num_buckets),
        grid=(N // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_buckets,), jnp.int32),
        interpret=interpret,
    )(ids, valid.astype(jnp.int32))


def bucket_hist_ref(ids, valid, *, num_buckets: int):
    """Pure-jnp oracle."""
    oh = (ids[:, None] == jnp.arange(num_buckets)[None, :]) & valid.astype(bool)[:, None]
    return oh.sum(0).astype(jnp.int32)
