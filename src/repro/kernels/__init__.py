"""Pallas TPU kernels for the walk engine hot-spots (+ jnp oracles)."""

from . import rng
from .bucket_hist import bucket_hist_kernel, bucket_hist_ref
from .node2vec_ref import node2vec_step_ref
from .ops import alias_step, node2vec_step
from .pair_advance import WALK_TILE, fused_advance_pair, pair_advance_kernel

__all__ = [
    "bucket_hist_kernel",
    "bucket_hist_ref",
    "node2vec_step_ref",
    "fused_advance_pair",
    "pair_advance_kernel",
    "node2vec_step",
    "alias_step",
    "WALK_TILE",
    "rng",
]
