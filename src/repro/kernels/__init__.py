"""Pallas TPU kernels for the walk engine hot-spots (+ jnp oracles)."""

from .bucket_hist import bucket_hist_kernel, bucket_hist_ref
from .node2vec_ref import node2vec_step_ref
from .node2vec_step import WALK_TILE, node2vec_step_kernel
from .ops import alias_step, node2vec_step

__all__ = [
    "bucket_hist_kernel", "bucket_hist_ref", "node2vec_step_ref",
    "node2vec_step_kernel", "node2vec_step", "alias_step", "WALK_TILE",
]
