"""Pallas TPU kernel: one second-order (Node2vec) walk step over a resident
block pair — the compute hot-spot of the bi-block engine.

TPU adaptation of the paper's inner loop (DESIGN.md §2): the two resident
blocks (current + ancillary) are pinned in **VMEM** via BlockSpecs with a
constant index map — the VMEM twin of the paper's "two blocks in RAM".  The
walk batch streams through in tiles of ``WALK_TILE`` (grid dimension 0), so
per grid step the working set is

    2 * ME * (4 + 4 + 4) bytes   (indices + alias J + alias q, both blocks)
  + 2 * (MV+1) * 4               (indptr)
  + WALK_TILE * small            (walk fields + uniforms)

which bounds the usable block size at roughly ME ≈ 400–500 K edges for a
16 MB VMEM part — that is the TPU-native answer to the paper's "Block Size"
knob (§7.6.2), and `repro.configs.grasorw` sets it accordingly.

All lane work is VPU-friendly: alias draw (2 gathers + select), fixed-depth
binary-search membership (log2(ME) rounds of gather + compare), one accept
select.  Gathers use per-lane dynamic indices into the VMEM-resident pair
(Mosaic vector gather).  No MXU use — this kernel is memory/VPU bound, which
is exactly why the paper's block scheduling (not FLOPs) decides throughput.

The rejection loop is *unrolled* ``k_max`` times (static), matching the
engine's fori_loop; uniforms are supplied as an input so the kernel is a
pure function (validated bit-exactly against ``node2vec_ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["node2vec_step_kernel", "WALK_TILE"]

WALK_TILE = 512


def _kernel(
    pair_start_ref,  # [2]        SMEM-ish scalars (kept in VMEM for interpret)
    pair_nverts_ref,  # [2]
    indptr_ref,      # [2, MV+1]  VMEM, whole pair resident
    indices_ref,     # [2, ME]
    alias_j_ref,     # [2, ME]
    alias_q_ref,     # [2, ME]
    prev_ref,        # [T]
    cur_ref,         # [T]
    hop_ref,         # [T]
    active_ref,      # [T] int32 (bool as int)
    unif_ref,        # [T, k_max, 3]
    z_ref,           # [T] out: next vertex (= cur where not moved)
    moved_ref,       # [T] out: int32 1 where a step was committed
    *,
    p: float,
    q: float,
    order: int,
    k_max: int,
    n_iters: int,
    has_alias: bool,
):
    ME = indices_ref.shape[1]
    start = pair_start_ref[...]
    nverts = pair_nverts_ref[...]
    indptr = indptr_ref[...]
    flat_indices = indices_ref[...].reshape(-1)
    prev = prev_ref[...]
    cur = cur_ref[...]
    hop = hop_ref[...]
    active = active_ref[...] > 0
    unif = unif_ref[...]
    max_bias = max(1.0, 1.0 / p, 1.0 / q)

    def locate(v):
        in0 = (v >= start[0]) & (v < start[0] + nverts[0])
        slot = jnp.where(in0, 0, 1).astype(jnp.int32)
        row = jnp.clip(v - start[slot], 0, indptr.shape[1] - 2)
        in1 = (v >= start[1]) & (v < start[1] + nverts[1])
        return slot, row, in0 | in1

    slot, row, resident = locate(cur)
    row_start = indptr[slot, row]
    deg = indptr[slot, row + 1] - row_start
    movable = active & resident & (deg > 0)
    deg_c = jnp.maximum(deg, 1)

    if order == 2:
        uslot, urow, _ = locate(prev)
        u_start = indptr[uslot, urow]
        ulo = uslot * ME + u_start
        uhi = ulo + (indptr[uslot, urow + 1] - u_start)

    def binsearch(z):
        """z in sorted flat_indices[ulo:uhi]? fixed-depth lower bound."""
        lo, hi = ulo, uhi

        def half(carry, _):
            lo_, hi_ = carry
            mid = (lo_ + hi_) // 2
            val = flat_indices[jnp.clip(mid, 0, flat_indices.shape[0] - 1)]
            valid = lo_ < hi_
            go_r = valid & (val < z)
            lo_ = jnp.where(go_r, mid + 1, lo_)
            hi_ = jnp.where(valid & ~go_r, mid, hi_)
            return (lo_, hi_), None

        (lo_f, _), _ = jax.lax.scan(half, (lo, hi), None, length=n_iters)
        pos = jnp.clip(lo_f, 0, flat_indices.shape[0] - 1)
        return (lo_f < uhi) & (flat_indices[pos] == z)

    z = cur
    accepted = ~movable
    for kk in range(k_max):
        u1, u2, u3 = unif[:, kk, 0], unif[:, kk, 1], unif[:, kk, 2]
        kloc = jnp.minimum((u1 * deg_c).astype(jnp.int32), deg_c - 1)
        idx = slot * ME + row_start + kloc
        if has_alias:
            aq = alias_q_ref[...].reshape(-1)
            aj = alias_j_ref[...].reshape(-1)
            kloc = jnp.where(u2 >= aq[idx], aj[idx], kloc)
            idx = slot * ME + row_start + kloc
        zk = flat_indices[idx]
        if order == 2:
            memb = binsearch(zk)
            bias = jnp.where(zk == prev, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
            acc_p = jnp.where(hop == 0, 1.0, bias / max_bias)
        else:
            acc_p = jnp.ones_like(u3)
        last = kk == k_max - 1
        take = (~accepted) & movable & ((u3 < acc_p) | last)
        z = jnp.where(take, zk, z)
        accepted = accepted | take

    z_ref[...] = z
    moved_ref[...] = movable.astype(jnp.int32)


def node2vec_step_kernel(
    pair_start,
    pair_nverts,
    indptr,
    indices,
    alias_j,
    alias_q,
    prev,
    cur,
    hop,
    active,
    unif,
    *,
    p: float = 1.0,
    q: float = 1.0,
    order: int = 2,
    k_max: int = 4,
    n_iters: int = 24,
    has_alias: bool = False,
    interpret: bool = True,
    walk_tile: int = WALK_TILE,
):
    """pl.pallas_call wrapper: grid over walk tiles; pair pinned in VMEM.

    ``prev/cur/hop/active`` are [N] with N a multiple of ``walk_tile``;
    ``unif`` is [N, k_max, 3] uniform(0,1) draws.  Returns (z, moved).
    """
    N = prev.shape[0]
    if N % walk_tile:
        raise ValueError(f"walk count {N} must be a multiple of {walk_tile}")
    grid = (N // walk_tile,)
    MV1 = indptr.shape[1]
    ME = indices.shape[1]

    pair_spec = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    walk_spec = pl.BlockSpec((walk_tile,), lambda i: (i,))
    unif_spec = pl.BlockSpec((walk_tile, k_max, 3), lambda i: (i, 0, 0))

    kern = functools.partial(
        _kernel, p=p, q=q, order=order, k_max=k_max, n_iters=n_iters,
        has_alias=has_alias,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pair_spec((2,)),
            pair_spec((2,)),
            pair_spec((2, MV1)),
            pair_spec((2, ME)),
            pair_spec((2, ME)),
            pair_spec((2, ME)),
            walk_spec,
            walk_spec,
            walk_spec,
            walk_spec,
            unif_spec,
        ],
        out_specs=[walk_spec, walk_spec],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        interpret=interpret,
    )(
        pair_start, pair_nverts, indptr, indices, alias_j, alias_q,
        prev, cur, hop, active.astype(jnp.int32), unif,
    )
