"""Pure-jnp oracle for the node2vec_step kernel (bit-exact same math)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["node2vec_step_ref"]


def node2vec_step_ref(
    pair_start,
    pair_nverts,
    indptr,
    indices,
    alias_j,
    alias_q,
    prev,
    cur,
    hop,
    active,
    unif,
    *,
    p: float = 1.0,
    q: float = 1.0,
    order: int = 2,
    k_max: int = 4,
    n_iters: int = 24,
    has_alias: bool = False,
):
    """Same contract as ``node2vec_step_kernel`` (interpret or TPU)."""
    ME = indices.shape[1]
    flat_indices = indices.reshape(-1)
    max_bias = max(1.0, 1.0 / p, 1.0 / q)
    active = active.astype(bool)

    def locate(v):
        in0 = (v >= pair_start[0]) & (v < pair_start[0] + pair_nverts[0])
        slot = jnp.where(in0, 0, 1).astype(jnp.int32)
        row = jnp.clip(v - pair_start[slot], 0, indptr.shape[1] - 2)
        in1 = (v >= pair_start[1]) & (v < pair_start[1] + pair_nverts[1])
        return slot, row, in0 | in1

    slot, row, resident = locate(cur)
    row_start = indptr[slot, row]
    deg = indptr[slot, row + 1] - row_start
    movable = active & resident & (deg > 0)
    deg_c = jnp.maximum(deg, 1)

    if order == 2:
        uslot, urow, _ = locate(prev)
        u_start = indptr[uslot, urow]
        ulo = uslot * ME + u_start
        uhi = ulo + (indptr[uslot, urow + 1] - u_start)

    from repro.core.sampling import searchsorted_rows

    z = cur
    accepted = ~movable
    for kk in range(k_max):
        u1, u2, u3 = unif[:, kk, 0], unif[:, kk, 1], unif[:, kk, 2]
        kloc = jnp.minimum((u1 * deg_c).astype(jnp.int32), deg_c - 1)
        idx = slot * ME + row_start + kloc
        if has_alias:
            kloc = jnp.where(
                u2 >= alias_q.reshape(-1)[idx], alias_j.reshape(-1)[idx], kloc
            )
            idx = slot * ME + row_start + kloc
        zk = flat_indices[idx]
        if order == 2:
            memb = searchsorted_rows(flat_indices, ulo, uhi, zk, n_iters=n_iters)
            bias = jnp.where(zk == prev, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
            acc_p = jnp.where(hop == 0, 1.0, bias / max_bias)
        else:
            acc_p = jnp.ones_like(u3)
        last = kk == k_max - 1
        take = (~accepted) & movable & ((u3 < acc_p) | last)
        z = jnp.where(take, zk, z)
        accepted = accepted | take

    return z, movable.astype(jnp.int32)
