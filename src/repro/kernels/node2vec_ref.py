"""Independent pure-jnp oracle for one fused-kernel hop (view-pair layout).

Deliberately shares *no* search code with the kernel or the engine impl:
row lookup and neighborhood membership are dense comparison sweeps over the
flat packed arrays (exact lower bounds, no binary search), so a bug in the
fixed-iteration searches cannot cancel out of the comparison.  Uniforms are
an explicit input — the caller supplies the counter-keyed draws (see
:mod:`repro.kernels.rng`), keeping this a pure function.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["node2vec_step_ref"]


def node2vec_step_ref(
    vids,      # [SV] i32 — both slots' sorted global vertex ids, concatenated
    nverts,    # [2] i32
    vid_base,  # [2] i32
    indptr,    # [SP] i32
    ptr_base,  # [2] i32
    indices,   # [SE] i32
    ind_base,  # [2] i32
    alias_j,   # [SE] i32 ([1] dummy if not has_alias)
    alias_q,   # [SE] f32
    prev,      # [N] i32
    cur,       # [N] i32
    hop,       # [N] i32
    active,    # [N] bool
    unif,      # [N, k_max, 3] f32 — counter-keyed uniforms, caller-supplied
    *,
    p: float = 1.0,
    q: float = 1.0,
    order: int = 2,
    k_max: int = 4,
    has_alias: bool = False,
):
    """One walk hop; same decision sequence as the fused kernel's loop body.
    Returns ``(z, moved)``."""
    pf, qf = jnp.float32(p), jnp.float32(q)
    max_bias = jnp.maximum(1.0, jnp.maximum(1.0 / pf, 1.0 / qf))
    active = active.astype(bool)
    v_ar = jnp.arange(vids.shape[0])
    e_ar = jnp.arange(indices.shape[0])

    def locate(v):
        """Dense exact lower bound per slot: row = #{vids in segment < v}."""
        vcol = v[:, None]
        seg0 = (v_ar >= vid_base[0]) & (v_ar < vid_base[0] + nverts[0])
        seg1 = (v_ar >= vid_base[1]) & (v_ar < vid_base[1] + nverts[1])
        row0 = jnp.sum(seg0 & (vids[None, :] < vcol), axis=1).astype(jnp.int32)
        row1 = jnp.sum(seg1 & (vids[None, :] < vcol), axis=1).astype(jnp.int32)
        found0 = jnp.any(seg0 & (vids[None, :] == vcol), axis=1)
        found1 = jnp.any(seg1 & (vids[None, :] == vcol), axis=1)
        slot = jnp.where(found0, 0, 1).astype(jnp.int32)
        row = jnp.where(found0, row0, row1)
        return slot, row, found0 | found1

    slot, row, resident = locate(cur)
    row_start = indptr[ptr_base[slot] + row]
    deg = indptr[ptr_base[slot] + row + 1] - row_start
    movable = active & resident & (deg > 0)
    deg_c = jnp.maximum(deg, 1)

    if order == 2:
        uslot, urow, _ = locate(prev)
        u_start = indptr[ptr_base[uslot] + urow]
        ulo = ind_base[uslot] + u_start
        uhi = ulo + (indptr[ptr_base[uslot] + urow + 1] - u_start)

    z = cur
    accepted = ~movable
    for kk in range(k_max):
        u1, u2, u3 = unif[:, kk, 0], unif[:, kk, 1], unif[:, kk, 2]
        kloc = jnp.minimum((u1 * deg_c).astype(jnp.int32), deg_c - 1)
        idx = ind_base[slot] + row_start + kloc
        if has_alias:
            kloc = jnp.where(u2 >= alias_q[idx], alias_j[idx], kloc)
            idx = ind_base[slot] + row_start + kloc
        zk = indices[idx]
        if order == 2:
            in_row = (e_ar >= ulo[:, None]) & (e_ar < uhi[:, None])
            memb = jnp.any(in_row & (indices[None, :] == zk[:, None]), axis=1)
            bias = jnp.where(zk == prev, 1.0 / pf, jnp.where(memb, 1.0, 1.0 / qf))
            acc_p = jnp.where(hop == 0, 1.0, bias / max_bias)
        else:
            acc_p = jnp.ones_like(u3)
        last = kk == k_max - 1
        take = (~accepted) & movable & ((u3 < acc_p) | last)
        z = jnp.where(take, zk, z)
        accepted = accepted | take

    return z, movable.astype(jnp.int32)
