"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936, QKV bias  [hf:Qwen/Qwen1.5-0.5B]."""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        d_model=1024,
        n_layers=24,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab_size=151_936,
        segments=((("attn+mlp",), 24),),
        qkv_bias=True,
        rope_theta=1e6,
        mlp_type="swiglu",
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        qkv_bias=True,
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
