"""Architecture config registry: ``get_config("<arch-id>")``.

One module per assigned architecture (exact public-literature configs), plus
``grasorw`` — the paper's own graph-task configuration.  Shape sets are in
:data:`SHAPES`; applicability rules (long_500k only for sub-quadratic archs,
decode only for archs with a decoder) are encoded on the config.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.common import ModelConfig

from . import (
    deepseek_v2_236b,
    internvl2_1b,
    llama32_1b,
    mamba2_27b,
    mixtral_8x22b,
    phi3_mini_38b,
    qwen15_05b,
    recurrentgemma_2b,
    whisper_tiny,
    yi_34b,
)

_MODULES = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "qwen1.5-0.5b": qwen15_05b,
    "llama3.2-1b": llama32_1b,
    "phi3-mini-3.8b": phi3_mini_38b,
    "yi-34b": yi_34b,
    "whisper-tiny": whisper_tiny,
    "mamba2-2.7b": mamba2_27b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "internvl2-1b": internvl2_1b,
}

ARCH_IDS: List[str] = list(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    try:
        return _MODULES[arch_id].config()
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")


def reduced_config(arch_id: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _MODULES[arch_id].reduced()


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    spec = SHAPES[shape]
    if spec.kind == "decode" and cfg.skip_decode:
        return False
    if spec.name == "long_500k" and not cfg.subquadratic:
        return False
    return True
