"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 attn:recurrent.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
lru_width=2560, local window 2048  [arXiv:2402.19427].
Sub-quadratic (local attention + linear recurrence) -> runs long_500k.
Pattern: (rglru, rglru, local) repeated; 26 = 8*3 + 2 trailing recurrents.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        d_model=2560,
        n_layers=26,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        segments=(
            (("rglru+mlp", "rglru+mlp", "local+mlp"), 8),
            (("rglru+mlp", "rglru+mlp"), 1),
        ),
        window=2048,
        mlp_type="geglu",
        lru_width=2560,
        conv_width=4,
        rope_theta=1e4,
        subquadratic=True,
        tie_embeddings=True,  # Griffin/Gemma tie in/out embeddings
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-reduced",
        d_model=64,
        n_layers=3,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        segments=((("rglru+mlp", "rglru+mlp", "local+mlp"), 1),),
        window=16,
        mlp_type="geglu",
        lru_width=64,
        conv_width=4,
        subquadratic=True,
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
