"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32, head_dim 96)
d_ff=8192 vocab=32064, RoPE + SwiGLU  [arXiv:2404.14219]."""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        d_model=3072,
        n_layers=32,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32_064,
        segments=((("attn+mlp",), 32),),
        rope_theta=1e4,
        mlp_type="swiglu",
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
