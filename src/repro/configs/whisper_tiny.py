"""whisper-tiny [audio]: enc-dec, 4+4L d_model=384 6H d_ff=1536
vocab=51865, conv frontend STUB (input_specs supplies frame embeddings)
[arXiv:2212.04356].

Pure full attention -> long_500k skipped. Vocab padded 51865 -> 51968 for
16-way shardability (DESIGN.md §4.1).
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        d_model=384,
        n_layers=4,  # decoder layers
        n_encoder_layers=4,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        segments=((("attn+mlp",), 4),),  # decoder structure (used for caches)
        mlp_type="gelu",
        learned_pos=True,
        max_pos=32_768,
        frontend="audio",
        train_microbatches=1,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced",
        d_model=64,
        n_layers=2,
        n_encoder_layers=2,
        n_heads=2,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        mlp_type="gelu",
        learned_pos=True,
        max_pos=128,
        frontend="audio",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
