"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8, head_dim 128)
d_ff=16384, 8 experts top-2, sliding-window attention  [arXiv:2401.04088].

8 experts < 16-way model axis -> experts are tensor-parallel (per-expert
FFN dim sharded), not expert-parallel.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        d_model=6144,
        n_layers=56,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32_768,
        segments=((("local+moe",), 56),),  # SWA + MoE every layer
        window=4096,
        n_experts=8,
        top_k=2,
        moe_d_ff=16384,
        moe_shard_experts=True,
        moe_virtual_split=2,  # 8 experts x 2 halves = 16-way EP (see Perf log)
        rope_theta=1e6,
        mlp_type="swiglu",
        train_microbatches=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        segments=((("local+moe",), 2),),
        window=32,
        n_experts=4,
        top_k=2,
        moe_d_ff=128,
        capacity_factor=8.0,  # no token drops in the smoke configs
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
