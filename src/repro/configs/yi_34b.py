"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8, head_dim 128)
d_ff=20480 vocab=64000, llama-arch  [arXiv:2403.04652].

56 heads is NOT divisible by the 16-way model axis — the sharding rules
shard the flattened head*dim projections (7168 % 16 == 0) and never the
head axis, so this config needs no special casing (DESIGN.md §4.1).
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        d_model=7168,
        n_layers=60,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64_000,
        segments=((("attn+mlp",), 60),),
        rope_theta=5e6,
        mlp_type="swiglu",
        train_microbatches=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=7,  # keep the non-power-of-two head count in the smoke test
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
