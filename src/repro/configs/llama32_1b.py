"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256  [hf:meta-llama/Llama-3.2-1B]."""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        d_model=2048,
        n_layers=16,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128_256,
        segments=((("attn+mlp",), 16),),
        rope_theta=5e5,
        mlp_type="swiglu",
        tie_embeddings=True,  # llama 3.2 ties in/out embeddings
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
