"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128,
expand=2 (d_inner 5120), head_dim 64 (80 heads), vocab=50280 — SSD
[arXiv:2405.21060].  O(1)-state decode -> runs long_500k.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        d_model=2560,
        n_layers=64,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        segments=((("ssd",), 64),),
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        conv_width=4,
        subquadratic=True,
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-reduced",
        d_model=64,
        n_layers=3,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        segments=((("ssd",), 3),),
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        conv_width=4,
        subquadratic=True,
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
