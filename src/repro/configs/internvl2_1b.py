"""internvl2-1b [vlm]: InternLM2 backbone 24L d_model=896 14H (GQA kv=2)
d_ff=4864 vocab=151655; InternViT frontend is a STUB — input_specs supplies
precomputed patch embeddings prepended to the token sequence
[arXiv:2404.16821].
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig

NUM_PATCHES = 256  # stub frontend: one image -> 256 patch embeddings


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        d_model=896,
        n_layers=24,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_655,
        segments=((("attn+mlp",), 24),),
        rope_theta=1e6,
        mlp_type="swiglu",
        frontend="vision",
        num_prefix=NUM_PATCHES,
        train_microbatches=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-reduced",
        d_model=64,
        n_layers=2,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        segments=((("attn+mlp",), 2),),
        mlp_type="swiglu",
        frontend="vision",
        num_prefix=8,
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
