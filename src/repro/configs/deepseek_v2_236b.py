"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512,
160 routed experts top-6 + 2 shared, expert d_ff=1536, vocab=102400
[arXiv:2405.04434].

First layer is dense (d_ff 12288); remaining 59 are MoE.  160 % 16 == 0 ->
true expert parallelism over the model axis (XLA all_to_all dispatch).
MLA decode uses the absorbed formulation (latent-space attention).
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        d_model=5120,
        n_layers=60,
        n_heads=128,
        n_kv_heads=128,  # MLA: full MHA over latent (spec lists kv=128)
        head_dim=128,
        d_ff=12288,  # the dense first layer
        vocab_size=102_400,
        segments=(
            (("mla+mlp",), 1),
            (("mla+moe",), 59),
        ),
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        moe_shard_experts=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        rope_theta=1e4,
        mlp_type="swiglu",
        train_microbatches=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-reduced",
        d_model=64,
        n_layers=3,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        segments=(
            (("mla+mlp",), 1),
            (("mla+moe",), 2),
        ),
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        moe_d_ff=64,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        capacity_factor=8.0,  # no token drops in the smoke configs
        mlp_type="swiglu",
        dtype=jnp.float32,  # CPU smoke tests execute; f32 avoids CPU bf16-dot gaps
        remat_policy="none",
    )
