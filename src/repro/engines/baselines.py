"""Baseline out-of-core engines from the paper's evaluation (§7).

* :class:`PlainBucketEngine` — the PB baseline of §7.3 (buckets, two block
  slots, but traditional walk storage, state-aware current scheduling and a
  0..N_B-1 ancillary sweep).
* :class:`SOGWEngine` — Second-Order GraphWalker (§7.1): one current block,
  per-walk random vertex I/O for the previous vertex's adjacency; with
  ``static_cache`` it becomes SGSC (static top-degree vertex cache).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import BlockedGraph, block_of
from repro.core.scheduler import make_scheduler
from repro.core.stats import SSD, DevicePreset
from repro.core.transition import WalkTask
from repro.core.walk import WalkBatch

from .base import EngineBase, WalkResult

__all__ = ["PlainBucketEngine", "SOGWEngine"]


class PlainBucketEngine(EngineBase):
    """§7.3 baseline: traditional walk storage (B(cur)), state-aware current
    scheduling (GraphWalker's max-sum), ancillary sweep b0..b_{N_B-1}."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.scheduler = make_scheduler("max_sum", bg.num_blocks, self.seed)

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        assoc = block_of(self.bg.block_starts, batch.cur)
        for b in np.unique(assoc):
            m = assoc == b
            self.pool.push(int(b), batch.select(m), wid[m])

    def _run(self) -> WalkResult:
        self._initialize()
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * self.bg.num_blocks * 4 + 10:
                raise RuntimeError("engine failed to converge (bug)")
            b = self.scheduler.next_block(self.pool.counts, self.pool.min_hop)
            if b is None:
                break
            batch, wid = self.pool.load(b)
            if len(batch) == 0:
                continue
            self.stats.time_slots += 1
            self.stats.supersteps += 1
            # state-aware scheduling jumps around: current block load is a
            # random block I/O (the paper's point about sequential wins)
            self.pair.set_slot(0, self.blocks.get_view(b, sequential=False))
            # walks live with B(cur); bucket key = B(prev) (plain bucketing)
            pre_blk = block_of(self.bg.block_starts, batch.prev)
            for i in range(self.bg.num_blocks):
                m = pre_blk == i
                if not m.any():
                    continue
                bucket, bwid = batch.select(m), wid[m]
                self.stats.bucket_executions += 1
                # the linear sweep makes the next ancillary block predictable
                nxt = next(
                    (j for j in range(i + 1, self.bg.num_blocks) if (pre_blk == j).any()),
                    None,
                )
                if nxt is not None:
                    self.blocks.prefetch(nxt)
                seq = i == b + 1  # only the successor read is sequential
                self.pair.set_slot(1, self.blocks.get_view(i, sequential=seq))
                bucket, alive = self._advance(bucket, bwid)
                bucket, bwid = self._retire(bucket, bwid, alive)
                self._persist(bucket, bwid)
        return self.result()


class SOGWEngine(EngineBase):
    """Second-order GraphWalker: one current block; every walk whose stored
    previous vertex lies outside it pays a random vertex I/O (the paper's
    Fig. 1a bottleneck).  ``static_cache=True`` adds SGSC's top-degree cache
    sized to one block's edge budget."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        static_cache: bool = False,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.scheduler = make_scheduler("max_sum", bg.num_blocks, self.seed)
        self.cached = np.zeros(bg.num_vertices, bool)
        if static_cache:
            deg = bg.degrees.astype(np.int64)
            order = np.argsort(-deg)
            budget = int(bg.block_nedges.max())
            csum = np.cumsum(deg[order])
            k = int(np.searchsorted(csum, budget, side="right"))
            top = order[: max(k, 1)]
            self.cached[top] = True
            # cache initialisation is I/O (the paper charges it to I/O time)
            self.stats.vertex_load(top.size, int(8 * top.size + 4 * deg[top].sum()))

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        assoc = block_of(self.bg.block_starts, batch.cur)
        for b in np.unique(assoc):
            m = assoc == b
            self.pool.push(int(b), batch.select(m), wid[m])

    def _run(self) -> WalkResult:
        self._initialize()
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * self.bg.num_blocks * 4 + 10:
                raise RuntimeError("engine failed to converge (bug)")
            b = self.scheduler.next_block(self.pool.counts, self.pool.min_hop)
            if b is None:
                break
            batch, wid = self.pool.load(b)
            if len(batch) == 0:
                continue
            self.stats.time_slots += 1
            self.stats.supersteps += 1
            view_b = self.blocks.get_view(b, sequential=False)
            # vertex I/Os: SECOND-order walks must fetch the stored previous
            # vertex's adjacency when it lies outside the current block
            # (first-order models never touch prev — paper Fig. 1a)
            pre_blk = block_of(self.bg.block_starts, batch.prev)
            outside = (
                (pre_blk != b) & (batch.hop > 0)
                if self.order == 2
                else np.zeros(len(batch), bool)
            )
            needs_io = outside & ~self.cached[batch.prev]
            if needs_io.any():
                vs = batch.prev[needs_io]
                deg = self.bg.degrees[vs].astype(np.int64)
                # per-walk light I/O — SOGW does not dedupe across walks
                self.stats.vertex_load(int(needs_io.sum()), int(8 * needs_io.sum() + 4 * deg.sum()))
            # the fetched (or cached) out-of-block prev adjacencies become a
            # gathered view in slot 1, so the rejection test probes the true
            # rows the engine just paid for — the walks are exactly the
            # oracle's, not an approximation
            self.pair.set_slot(0, view_b)
            if outside.any():
                self.pair.set_slot(1, self.blocks.gather_view(np.unique(batch.prev[outside])))
            else:
                self.pair.set_slot(1, view_b)
            batch, alive = self._advance(batch, wid)
            batch, wid = self._retire(batch, wid, alive)
            self._persist(batch, wid)
        return self.result()
