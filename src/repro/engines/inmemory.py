"""In-memory oracle / corpus generator (whole-graph fast path).

Runs the same jitted view-pair kernel as the out-of-core engines with the
whole graph packed into a single full view.  Because every random draw is
keyed per ``(walk id, hop)`` off the task seed, the oracle's walks are
*bit-identical* to the walks any out-of-core engine samples for the same
task — the strongest possible correctness pin for the engines.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import BlockedGraph
from repro.core.stats import IOStats
from repro.core.transition import Node2vec, WalkTask

from .base import WalkResult
from .step import advance_pair, pow2_pad, remap_search_iters

__all__ = ["InMemoryWalker"]


class InMemoryWalker:
    """Whole-graph walker: one jit'd while_loop over steps.  Ground truth for
    engine tests and the corpus generator feeding the LM data pipeline."""

    def __init__(self, bg: BlockedGraph, task: WalkTask, *, k_max: int = 16):
        if not hasattr(bg, "graph"):
            # e.g. repro.io.DiskBlockedGraph: rebuild the host CSR explicitly
            raise TypeError(
                "InMemoryWalker needs the in-RAM BlockedGraph; for a disk "
                "backend, wrap bg.read_csr() in a BlockedGraph first"
            )
        self.bg = bg
        self.task = task
        is_plain = isinstance(task.model, Node2vec) and task.model.p == task.model.q == 1.0
        self.k_max = 1 if is_plain else k_max
        if task.model.order == 1:
            self.k_max = 1

    def run(self, *, record_walks: bool = True) -> WalkResult:
        bg, task = self.bg, self.task
        g = bg.graph
        stats = IOStats()
        src = task.initial_walks(g.num_vertices)
        n = src.shape[0]
        V = g.num_vertices
        # the whole graph as one full view; slot 1 aliases slot 0
        vids = np.arange(V, dtype=np.int32)
        nverts = np.array([V, V], np.int32)
        base0 = np.zeros(2, np.int32)
        indptr = g.indptr.astype(np.int32)
        indices = g.indices.astype(np.int32)
        has_alias = g.weights is not None
        if has_alias:
            from repro.core.sampling import build_alias_rows

            alias_j, alias_q = build_alias_rows(indptr, V, max(g.num_edges, 1), g.weights)
        else:
            alias_j = np.zeros(1, np.int32)
            alias_q = np.ones(1, np.float32)

        N = pow2_pad(n)
        pad = N - n
        pad32 = lambda x: jnp.asarray(np.concatenate([x.astype(np.int32), np.zeros(pad, np.int32)]))
        alive = jnp.asarray(np.concatenate([np.ones(n, bool), np.zeros(pad, bool)]))
        wid = pad32(np.arange(n, dtype=np.int64))
        v_iters = remap_search_iters(V)
        t0 = time.perf_counter()
        out = advance_pair(
            jnp.asarray(vids),
            jnp.asarray(nverts),
            jnp.asarray(base0),
            jnp.asarray(indptr),
            jnp.asarray(base0),
            jnp.asarray(indices),
            jnp.asarray(base0),
            jnp.asarray(alias_j),
            jnp.asarray(alias_q),
            wid,
            pad32(src),
            pad32(src),
            pad32(np.zeros(n)),
            alive,
            jax.random.PRNGKey(task.seed),
            jnp.int32(task.length),
            jnp.float32(task.decay),
            jnp.float32(getattr(task.model, "p", 1.0)),
            jnp.float32(getattr(task.model, "q", 1.0)),
            order=task.model.order,
            k_max=self.k_max,
            n_iters=int(np.ceil(np.log2(max(g.num_edges, 2)))) + 2,
            v_iters=v_iters,
            record=record_walks,
            has_alias=has_alias,
            max_len=int(task.length),
        )
        prev_f, cur_f, hop_f, alive_f, steps, trace = jax.tree.map(
            np.asarray, jax.block_until_ready(out)
        )
        stats.exec_time = time.perf_counter() - t0
        stats.steps_sampled = int(steps)
        counts = np.bincount(cur_f[:n], minlength=g.num_vertices).astype(np.int64)
        corpus = None
        if record_walks:
            corpus = np.full((n, task.length + 1), -1, np.int32)
            corpus[:, 0] = src
            t = trace[:n]
            for h in range(1, task.length + 1):
                m = t[:, h] >= 0
                corpus[m, h] = t[m, h]
        return WalkResult(n, int(steps), counts, corpus, stats)
