"""Walk execution engines atop the :mod:`repro.io` storage layer.

* :class:`BiBlockEngine` — the paper's system (GraSorw): triangular bi-block
  scheduling (§4.2), skewed walk storage + bucket management (§4.3),
  bucket-extending (Alg. 2), learning-based block loading (§5).
* :class:`PlainBucketEngine` / :class:`SOGWEngine` — the §7 baselines.
* :class:`InMemoryWalker` — whole-graph fast path: the oracle for correctness
  tests and the corpus generator for LM training on small/medium graphs.

Every out-of-core engine persists walk state exclusively through an injected
:class:`repro.io.WalkPool` (``pool="memory"`` or ``"disk"``) and loads graph
blocks exclusively through a :class:`repro.io.BlockStore` (LRU cache +
background prefetch).  ``repro.core.engine`` re-exports everything here for
backward compatibility.
"""

from .base import EngineBase, ResidentPair, WalkResult, _DeviceBlockPair  # noqa: F401
from .baselines import PlainBucketEngine, SOGWEngine
from .biblock import BiBlockEngine
from .inmemory import InMemoryWalker
from .pipeline import BucketCursor, BucketPipeline
from .step import advance_pair, pair_advance_impl, pow2_pad

__all__ = [
    "EngineBase",
    "ResidentPair",
    "WalkResult",
    "BiBlockEngine",
    "BucketCursor",
    "BucketPipeline",
    "PlainBucketEngine",
    "SOGWEngine",
    "InMemoryWalker",
    "advance_pair",
    "pair_advance_impl",
    "pow2_pad",
]
