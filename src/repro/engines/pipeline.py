"""The staged async bi-block pipeline: what overlaps with what.

The serial bi-block loop executes each time slot as
``pool load -> bucket split -> ancillary view load -> advance -> persist``
with only a one-bucket-ahead partial-view prefetch.  This module turns the
slot into an explicit three-stage pipeline driven from the
:class:`~repro.core.scheduler.TimeSlotPlan`:

* **walk stage** (walk-pool writer thread) — persists ride a sequenced
  writer queue (:class:`repro.io.AsyncWalkPool`), and the *next* slot's pool
  drain + bucket split run there as a ``drain_async`` preload while the
  current slot advances;
* **view stage** (block-store prefetch thread) — the next slot's
  current-block view and the next bucket's ancillary view (full or
  activated, per the tentative LBL decision) build via
  :meth:`repro.io.BlockStore.schedule`;
* **execute stage** (main thread) — the jitted ``advance_pair`` call on the
  resident view pair.

Determinism is structural, not lucky: a preload is a FIFO job on the writer
queue, so it observes exactly the pushes enqueued before it in program
order — a *prefix* of the slot's walks.  Pools preserve push order, so
``prefix drain + remainder drain`` at slot start concatenates to what one
serial ``load`` would have returned, and with the counter-based per-walk
RNG the walks are bit-identical to the serial reference mode
(``async_pipeline=False``).  Prefetching never charges; the preload only
moves *when* walk reads happen, never what executes.

:class:`BucketCursor` replaces the serial engine's ``sorted(pending)``
rescan with an ordered min-heap cursor that tolerates Alg. 2
extension-grown buckets (extensions only target later blocks; buckets only
grow).
"""

from __future__ import annotations

import heapq
from concurrent.futures import Future
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.buckets import split_into_buckets
from repro.core.scheduler import TimeSlotPlan
from repro.core.stats import IOStats
from repro.core.walk import WALK_BYTES, WalkBatch
from repro.io import AsyncWalkPool, BlockStore, ShardedWalkPool

__all__ = ["BucketCursor", "BucketPipeline"]

#: pool types whose persists ride sequenced writer threads and whose
#: ``drain_async`` the pipeline can preload from — the single writer and
#: its keyspace-partitioned generalisation (one writer per shard)
SEQUENCED_POOLS = (AsyncWalkPool, ShardedWalkPool)


class BucketCursor:
    """Ordered cursor over one time slot's pending buckets.

    Bucket ids pop in strictly increasing order (the triangular ancillary
    order); Alg. 2 extensions merge in mid-slot without a rescan because
    they only ever target blocks *after* the executing one.  Equivalent to
    the serial ``sorted(k for k in pending if k > i)`` rescan, minus the
    O(buckets log buckets) per-bucket re-sort.
    """

    def __init__(self):
        self._pending: Dict[int, Tuple[WalkBatch, np.ndarray]] = {}
        self._heap: list = []

    def __len__(self) -> int:
        return len(self._pending)

    def __contains__(self, i: int) -> bool:
        return int(i) in self._pending

    def get(self, i: int) -> Optional[Tuple[WalkBatch, np.ndarray]]:
        return self._pending.get(int(i))

    def add(self, i: int, batch: WalkBatch, wid: np.ndarray) -> None:
        """Add walks to bucket ``i``, merging after any already queued (the
        subset-reuse invariant: buckets only grow)."""
        i = int(i)
        if i in self._pending:
            pb, pw = self._pending[i]
            self._pending[i] = (WalkBatch.concat([pb, batch]), np.concatenate([pw, wid]))
        else:
            self._pending[i] = (batch, wid)
            heapq.heappush(self._heap, i)

    def pop(self) -> Optional[Tuple[int, WalkBatch, np.ndarray]]:
        """Take the smallest pending bucket, or None when the slot is done."""
        while self._heap:
            i = heapq.heappop(self._heap)
            entry = self._pending.pop(i, None)
            if entry is not None:
                return i, entry[0], entry[1]
        return None

    def peek(self) -> Optional[int]:
        """The bucket id :meth:`pop` would return next (prefetch target)."""
        while self._heap and self._heap[0] not in self._pending:
            heapq.heappop(self._heap)
        return self._heap[0] if self._heap else None


class BucketPipeline:
    """Drives slot preloads and bucket-view prefetches for one engine run.

    With ``enabled=True`` the pool must be sequenced — an
    :class:`repro.io.AsyncWalkPool` or its sharded generalisation
    :class:`repro.io.ShardedWalkPool` — and :meth:`preload_slot` starts the
    next slot's drain + split on the writer owning that slot's shard (a
    sharded pool routes ``drain_async`` to the owning shard's FIFO, so
    drains for different blocks overlap each other too); with
    ``enabled=False`` every pool operation runs synchronously on the calling
    thread — the serial reference mode, bit-identical by construction.

    :meth:`acquire_slot` accounts the overlap: a slot served from a preload
    adds its spilled walk bytes to ``IOStats.overlapped_load_bytes``; a slot
    with no preload in flight (serial mode, the first slot of a run, a
    mispredicted next slot) counts into ``IOStats.pipeline_stall_slots``.
    Both are deterministic — they depend on the enqueue order, not on thread
    timing.
    """

    def __init__(
        self,
        *,
        pool,
        blocks: BlockStore,
        block_starts: np.ndarray,
        stats: IOStats,
        plan: TimeSlotPlan,
        enabled: bool = True,
    ):
        if enabled and not isinstance(pool, SEQUENCED_POOLS):
            raise ValueError(
                "async BucketPipeline needs a sequenced pool (AsyncWalkPool or ShardedWalkPool)"
            )
        self.pool = pool
        self.blocks = blocks
        self.block_starts = np.asarray(block_starts)
        self.stats = stats
        self.plan = plan
        self.enabled = enabled
        self.order = plan.order
        self._preloads: Dict[int, Future] = {}

    # -- slot state -----------------------------------------------------------
    def slot_has_walks(self, b: int) -> bool:
        """Live check the runner uses to decide whether slot ``b`` executes:
        walks in the pool *or* already handed to a preload.  Matches the
        serial ``pool.counts[b] > 0`` check exactly (eager counts + preload
        membership partition the same walks)."""
        return b in self._preloads or self.pool.counts[b] > 0

    def plan_next(self, b: int) -> Optional[int]:
        """The slot the plan schedules after ``b`` (wrapping into the next
        superstep), or None when nothing else is pending."""
        return self.plan.next_slot(b, self.slot_has_walks)

    # -- stage A: next-slot pool drain + bucket split ---------------------------
    def preload_slot(self, b: Optional[int]) -> None:
        """Start slot ``b``'s pool drain (+ bucket split, order 2) on the
        writer thread and its current-block view build on the prefetch
        thread, overlapping the current slot's advance."""
        if b is None or b in self._preloads or self.pool.counts[b] <= 0:
            return
        if not self.enabled:
            if self.order == 1:
                # the serial first-order engine already prefetched the next
                # current block (iteration scheduling); preserve that
                self.blocks.schedule([("full", b)])
            return
        transform = self._split_transform(b) if self.order == 2 else None
        self._preloads[b] = self.pool.drain_async(b, transform)
        self.blocks.schedule([("full", b)])

    def _split_transform(self, b: int):
        starts = self.block_starts

        def split(batch: WalkBatch, wid: np.ndarray):
            return split_into_buckets(starts, batch, b, wid)

        return split

    def acquire_slot(self, b: int):
        """Slot ``b``'s walks in exact serial push order: the preloaded
        prefix (if any) plus the post-preload remainder.  Returns a
        :class:`BucketCursor` for second-order slots, a ``(batch, wid)``
        pair for first-order ones."""
        fut = self._preloads.pop(b, None)
        if fut is None:
            self.stats.note_stall_slot()
            batch, wid = self.pool.load(b)
            return self._package(b, batch, wid, pre=None)
        payload, _n_walks, n_spilled = fut.result()
        self.stats.note_overlapped(n_spilled * WALK_BYTES)
        if self.pool.counts[b] > 0:  # pushed after the preload point
            batch, wid = self.pool.load(b)
        else:
            batch, wid = WalkBatch.empty(), np.zeros(0, np.int64)
        return self._package(b, batch, wid, pre=payload)

    def _package(self, b: int, batch: WalkBatch, wid: np.ndarray, pre):
        if self.order == 1:
            if pre is not None:
                pb, pw = pre
                batch = WalkBatch.concat([pb, batch])
                wid = np.concatenate([pw, wid])
            return batch, wid
        cursor = BucketCursor()
        if pre is not None:
            for i, (bb, ww) in pre.items():
                cursor.add(i, bb, ww)
        if len(batch):
            for i, (bb, ww) in split_into_buckets(self.block_starts, batch, b, wid).items():
                cursor.add(i, bb, ww)
        return cursor

    # -- teardown ---------------------------------------------------------------
    def finish(self) -> None:
        """End-of-run drain: waits out the writer queue so a persist-worker
        failure surfaces from ``run()`` even when the final slot never
        touched the pool again."""
        self._preloads.clear()
        if isinstance(self.pool, SEQUENCED_POOLS):
            self.pool.barrier()
