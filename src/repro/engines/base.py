"""Shared engine plumbing: resident view pair, walk pools, stats, advance.

Every out-of-core engine owns

* a :class:`repro.io.WalkPool` (``pool=``, ``"memory"`` or ``"disk"``) — the
  slow tier holding partially-finished walks between time slots; engines
  persist *exclusively* through it;
* a :class:`repro.io.BlockStore` — metered, cached, prefetching access to
  graph block *views*; engines load *exclusively* through it;
* a :class:`ResidentPair` — the two resident slots as packed device arrays
  (the "memory" tier of the paper).  Each slot holds a
  :class:`~repro.core.graph.BlockView` — a full block or a compacted
  *activated* view — so heterogeneously-sized views stack without padding
  one to the other's shape; per-slot sizes are pow2-bucketed to bound jit
  recompiles.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import BlockedGraph, BlockView, block_of
from repro.core.stats import SSD, DevicePreset, IOStats
from repro.core.transition import Node2vec, WalkTask
from repro.core.walk import WalkBatch
from repro.io import AsyncWalkPool, BlockStore, ShardedWalkPool, WalkPool, make_walk_pool
from repro.kernels.pair_advance import fused_advance_pair

from .step import VID_PAD, advance_pair, pow2_pad, remap_search_iters

__all__ = ["WalkResult", "EngineBase", "ResidentPair"]


@dataclasses.dataclass
class WalkResult:
    """Task output: endpoint histogram (PPR estimator), optional corpus."""

    num_walks: int
    steps_sampled: int
    endpoint_counts: np.ndarray  # [V] visits at termination
    corpus: Optional[np.ndarray]  # [num_walks, length+1] int32 or None
    stats: IOStats
    loader_summary: Optional[dict] = None
    block_store_counters: Optional[dict] = None

    def ppr_estimate(self) -> np.ndarray:
        tot = max(self.endpoint_counts.sum(), 1)
        return self.endpoint_counts / tot


class ResidentPair:
    """Two resident view slots packed into flat ragged device arrays.

    Unlike the fixed-shape block pair it replaces, each slot is padded to
    its *own* pow2-bucketed capacity, so an activated view costs
    ``O(activated vertices)`` device bytes next to a full block instead of
    being padded to the block maxima.  When both slots hold the same view
    (initialization, single-block engines) the segment is stored once and
    both slots alias it.
    """

    #: pow2 floor for activated-view capacities (vertices, edges)
    V_FLOOR = 64
    E_FLOOR = 256

    def __init__(self, bg: BlockedGraph, has_alias: bool, stats: Optional[IOStats] = None):
        self.bg = bg
        self.has_alias = has_alias
        self.stats = stats
        self.views: list[Optional[BlockView]] = [None, None]
        # pack-once-per-slot-change: packed segment + caps, keyed by the view
        # object resident in the slot (views are immutable once built)
        self._packed: list = [None, None]

    def set_slot(self, s: int, view: BlockView) -> None:
        if self.views[s] is not view:
            self._packed[s] = None
        self.views[s] = view

    def _packed_segment(self, s: int):
        view = self.views[s]
        if self._packed[s] is None:
            vc, ec = self._caps(view)
            self._packed[s] = (self._pack_segment(view, vc, ec, self.has_alias), vc, ec)
        return self._packed[s]

    # -- packing --------------------------------------------------------------
    def _caps(self, view: BlockView) -> Tuple[int, int]:
        """Padded (vertex, edge) capacity for one view.  Full views always
        pad to the graph maxima (one stable shape); activated views to a
        pow2 bucket of their own size."""
        if view.kind == "full":
            return self.bg.max_block_verts, self.bg.max_block_edges
        vc = min(pow2_pad(view.nverts, self.V_FLOOR), self.bg.max_block_verts)
        ec = min(pow2_pad(view.nedges, self.E_FLOOR), self.bg.max_block_edges)
        return max(vc, view.nverts), max(ec, view.nedges)

    @staticmethod
    def _pack_segment(view: BlockView, vc: int, ec: int, has_alias: bool):
        vids = np.full(vc, VID_PAD, np.int32)
        vids[: view.nverts] = view.vids
        indptr = np.full(vc + 1, view.nedges, np.int32)
        indptr[: view.nverts + 1] = view.indptr
        indices = np.full(ec, -1, np.int32)
        indices[: view.nedges] = view.indices
        if has_alias:
            aj = np.zeros(ec, np.int32)
            aq = np.ones(ec, np.float32)
            if view.alias_j is not None:
                aj[: view.nedges] = view.alias_j
                aq[: view.nedges] = view.alias_q
        else:
            aj = np.zeros(1, np.int32)
            aq = np.ones(1, np.float32)
        return vids, indptr, indices, aj, aq

    def device_args(self):
        """Pack both slots into the kernel's flat ragged arrays.  Returns
        ``(args, v_iters)`` — ``v_iters`` is the static binary-search depth
        for the remap lookup at this padded size."""
        v0, v1 = self.views
        dedupe = v1 is v0
        slots = [0] if dedupe else [0, 1]
        segs = []
        packed = []
        for s in slots:
            p, vc, ec = self._packed_segment(s)
            segs.append((self.views[s], vc, ec))
            packed.append(p)
        vids = np.concatenate([p[0] for p in packed])
        indptr = np.concatenate([p[1] for p in packed])
        indices = np.concatenate([p[2] for p in packed])
        if self.has_alias:
            alias_j = np.concatenate([p[3] for p in packed])
            alias_q = np.concatenate([p[4] for p in packed])
        else:
            alias_j = np.zeros(1, np.int32)
            alias_q = np.ones(1, np.float32)
        vc0 = segs[0][1]
        ec0 = segs[0][2]
        if dedupe:
            nverts = np.array([v0.nverts, v0.nverts], np.int32)
            vid_base = np.array([0, 0], np.int32)
            ptr_base = np.array([0, 0], np.int32)
            ind_base = np.array([0, 0], np.int32)
        else:
            nverts = np.array([v0.nverts, v1.nverts], np.int32)
            vid_base = np.array([0, vc0], np.int32)
            ptr_base = np.array([0, vc0 + 1], np.int32)
            ind_base = np.array([0, ec0], np.int32)
        if self.stats is not None:
            nbytes = 4 * (vids.size + indptr.size + indices.size)
            if self.has_alias:
                nbytes += 8 * indices.size
            self.stats.note_resident(nbytes)
        max_cap = max(vc for _, vc, _ in segs)
        v_iters = remap_search_iters(max_cap)
        args = (
            jnp.asarray(vids),
            jnp.asarray(nverts),
            jnp.asarray(vid_base),
            jnp.asarray(indptr),
            jnp.asarray(ptr_base),
            jnp.asarray(indices),
            jnp.asarray(ind_base),
            jnp.asarray(alias_j),
            jnp.asarray(alias_q),
        )
        return args, v_iters


class EngineBase:
    """Common state: walk pool ("disk"), block store, stats, bookkeeping.

    Engines are single-run objects and context managers: ``run()`` closes
    the storage layer on any exit (including a raise), ``close()`` is
    idempotent, and ``with Engine(...) as eng: eng.run()`` works too.
    """

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        k_max: int = 16,
        pool: Union[str, WalkPool] = "memory",
        pool_flush_walks: int = 1 << 18,
        pool_dir: Optional[str] = None,
        prefetch: bool = True,
        block_cache_blocks: int = 4,
        seed: Optional[int] = None,
        async_pipeline: bool = False,
        writer_queue: int = 64,
        pool_shards: int = 1,
        advance_impl: str = "jax",
        advance_interpret: bool = True,
        stats: Optional[IOStats] = None,
        block_store: Optional[BlockStore] = None,
        initial_walks: Optional[np.ndarray] = None,
        on_retire: Optional[Callable[[np.ndarray, np.ndarray], None]] = None,
        hot_blocks=None,
    ):
        self.bg = bg
        self.task = task
        # the serving seams: a query front end (repro.serve) passes a shared
        # IOStats + BlockStore so charges (and the hot-set pinning savings)
        # accumulate across the engine runs it drives, injects the admitted
        # queries' walk sources as `initial_walks`, and observes per-walk
        # terminations through `on_retire` to attribute endpoints per query
        if stats is None and block_store is not None:
            stats = block_store.stats
        self.stats = IOStats(preset) if stats is None else stats
        if block_store is not None and block_store.stats is not self.stats:
            raise ValueError(
                "a shared block_store must charge through the engine's IOStats "
                "(pass the store's stats, or no stats at all)"
            )
        self.on_retire = on_retire
        self.record_walks = record_walks
        self.k_max = k_max if isinstance(task.model, Node2vec) else 1
        if isinstance(task.model, Node2vec) and task.model.p == task.model.q == 1.0:
            self.k_max = 1  # acceptance prob is exactly 1 — no rejection needed
        self.pool_flush_walks = pool_flush_walks
        self.seed = task.seed if seed is None else seed
        self.order = task.model.order
        # backend-neutral surface: works for the in-RAM BlockedGraph and the
        # file-backed repro.io.DiskBlockedGraph alike
        self.has_alias = bg.has_weights
        if self.has_alias:
            bg.ensure_alias()
        self.n_iters = int(np.ceil(np.log2(max(bg.max_block_edges, 2)))) + 2
        # the advance lowering: "jax" (plain jitted impl) or "pallas" (the
        # fused multi-hop kernel, repro.kernels.pair_advance) — both draw
        # through kernels/rng, so their walks are bit-identical
        if advance_impl not in ("jax", "pallas"):
            raise ValueError(f"advance_impl must be 'jax' or 'pallas', got {advance_impl!r}")
        self.advance_impl = advance_impl
        self.advance_interpret = bool(advance_interpret)
        # counter-based RNG: one fixed base key; draws are keyed per
        # (walk id, hop), never per call — see repro.engines.step
        self._base_key = jax.random.PRNGKey(self.seed)
        V = bg.num_vertices
        self.endpoint_counts = np.zeros(V, np.int64)
        if initial_walks is None:
            src = task.initial_walks(V)
        else:
            src = np.asarray(initial_walks, dtype=np.int64)
        self.num_walks = src.shape[0]
        self.corpus = (
            np.full((self.num_walks, task.length + 1), -1, np.int32)
            if record_walks
            else None
        )
        if record_walks:
            self.corpus[:, 0] = src
        # the storage layer: walk pool ("disk" tier) + block store; with the
        # async pipeline the pool persists through a sequenced writer thread
        # (ticketed pushes — serial state sequence, off the critical path),
        # and pool_shards > 1 partitions the keyspace across that many
        # writers (one AsyncWalkPool-wrapped backend per shard)
        self.async_pipeline = bool(async_pipeline)
        self.writer_queue = writer_queue
        self.pool_shards = max(int(pool_shards), 1)
        if self.pool_shards > 1 and not self.async_pipeline:
            raise ValueError(
                "pool_shards > 1 requires the async pipeline: shards are "
                "per-shard sequenced writers (the serial reference mode has none)"
            )
        if self.pool_shards > 1 and not isinstance(pool, (str, ShardedWalkPool)):
            raise ValueError(
                "pool_shards > 1 needs a backend name (or a prebuilt ShardedWalkPool); "
                "a plain pool instance cannot be partitioned after construction"
            )
        if self.pool_shards > 1 and isinstance(pool, str):
            self.pool: WalkPool = ShardedWalkPool(
                pool,
                num_shards=self.pool_shards,
                num_blocks=bg.num_blocks,
                stats=self.stats,
                block_starts=bg.block_starts,
                flush_walks=pool_flush_walks,
                directory=pool_dir,
                max_queue=writer_queue,
            )
        else:
            self.pool = make_walk_pool(
                pool,
                num_blocks=bg.num_blocks,
                stats=self.stats,
                block_starts=bg.block_starts,
                flush_walks=pool_flush_walks,
                directory=pool_dir,
            )
            if self.async_pipeline and not isinstance(self.pool, (AsyncWalkPool, ShardedWalkPool)):
                self.pool = AsyncWalkPool(self.pool, stats=self.stats, max_queue=writer_queue)
        if block_store is not None:
            self.blocks = block_store
            self._owns_blocks = False
        else:
            self.blocks = BlockStore(
                bg,
                self.stats,
                enable_prefetch=prefetch,
                capacity=max(block_cache_blocks, 2),
            )
            self._owns_blocks = True
        if hot_blocks is not None:
            self.blocks.pin(hot_blocks)
        self._pending_init_src = src
        self.unfinished = self.num_walks
        self.pair = ResidentPair(bg, self.has_alias, self.stats)
        self._closed = False

    # -- pool plumbing ("disk" walk I/O) --------------------------------------
    @property
    def pool_counts(self) -> np.ndarray:
        return self.pool.counts

    @property
    def pool_min_hop(self) -> np.ndarray:
        return self.pool.min_hop

    # -- termination bookkeeping ----------------------------------------------
    def _retire(
        self,
        batch: WalkBatch,
        wid: np.ndarray,
        alive: np.ndarray,
    ) -> Tuple[WalkBatch, np.ndarray]:
        done = ~alive
        if done.any():
            ends = batch.cur[done]
            np.add.at(self.endpoint_counts, ends, 1)
            if self.on_retire is not None:
                self.on_retire(wid[done], ends)
            self.unfinished -= int(done.sum())
        keep = alive
        return batch.select(keep), wid[keep]

    def _record_trace(self, wid: np.ndarray, trace: np.ndarray) -> None:
        if self.corpus is None or wid.size == 0:
            return
        cols = np.nonzero((trace >= 0).any(axis=0))[0]
        for h in cols:
            col = trace[:, h]
            m = col >= 0
            self.corpus[wid[m], h] = col[m]

    # -- the jitted advance wrapper --------------------------------------------
    def _advance(self, batch: WalkBatch, wid: np.ndarray, alive: Optional[np.ndarray] = None):
        """Run advance_pair on the resident view pair; returns the updated
        host batch and alive mask.  ``alive`` masks walks already retired in
        a previous round of the same bucket (mid-advance extensions)."""
        n = len(batch)
        N = pow2_pad(n)
        pad = N - n

        def pad32(x, fill):
            return jnp.asarray(np.concatenate([x.astype(np.int32), np.full(pad, fill, np.int32)]))

        prev = pad32(batch.prev, 0)
        cur = pad32(batch.cur, 0)
        hop = pad32(batch.hop, 0)
        wid_dev = pad32(wid, 0)
        alive_host = np.ones(n, bool) if alive is None else alive
        alive_dev = jnp.asarray(np.concatenate([alive_host, np.zeros(pad, bool)]))
        pair_args, v_iters = self.pair.device_args()
        t0 = time.perf_counter()
        if self.advance_impl == "pallas":
            advance = partial(fused_advance_pair, interpret=self.advance_interpret)
        else:
            advance = advance_pair
        out = advance(
            *pair_args,
            wid_dev,
            prev,
            cur,
            hop,
            alive_dev,
            self._base_key,
            jnp.int32(self.task.length),
            jnp.float32(self.task.decay),
            jnp.float32(getattr(self.task.model, "p", 1.0)),
            jnp.float32(getattr(self.task.model, "q", 1.0)),
            order=self.order,
            k_max=self.k_max,
            n_iters=self.n_iters,
            v_iters=v_iters,
            record=self.record_walks,
            has_alias=self.has_alias,
            max_len=int(self.task.length),
        )
        prev_f, cur_f, hop_f, alive_f, steps, trace = jax.tree.map(
            np.asarray, jax.block_until_ready(out)
        )
        self.stats.exec_time += time.perf_counter() - t0
        self.stats.steps_sampled += int(steps)
        if self.record_walks:
            self._record_trace(wid, trace[:n])
        new_batch = WalkBatch(batch.src, prev_f[:n], cur_f[:n], hop_f[:n])
        return new_batch, alive_f[:n]

    # -- initialization stage (paper App. B step 1) -----------------------------
    def _initialize(self) -> None:
        """First-order init: advance walks inside their source block until
        they leave it or terminate, guaranteeing B(u) != B(v) for every
        persisted walk."""
        src = self._pending_init_src
        self._pending_init_src = None
        wid_all = np.arange(src.shape[0], dtype=np.int64)
        src_blocks = block_of(self.bg.block_starts, src)
        uniq = np.unique(src_blocks)
        for k, b in enumerate(uniq):
            view = self.blocks.get_view(int(b), sequential=True)
            if k + 1 < len(uniq):
                self.blocks.prefetch(int(uniq[k + 1]))
            self.pair.set_slot(0, view)
            self.pair.set_slot(1, view)
            m = src_blocks == b
            batch = WalkBatch(src[m], src[m], src[m], np.zeros(m.sum(), np.int32))
            wid = wid_all[m]
            batch, alive = self._advance(batch, wid)
            batch, wid = self._retire(batch, wid, alive)
            self._persist(batch, wid)

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        raise NotImplementedError

    def _run(self) -> WalkResult:
        raise NotImplementedError

    def run(self) -> WalkResult:
        """Execute the task.  The storage layer (prefetch thread, disk-pool
        spill dirs) is released on *any* exit — including the
        convergence-guard ``RuntimeError`` — so a failed run leaks nothing."""
        try:
            return self._run()
        finally:
            self.close()

    def close(self) -> None:
        """Release the storage layer: the prefetch thread and any spill
        files/temp dirs a disk pool owns.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._owns_blocks:
            self.blocks.close()
        self.pool.close()

    def __enter__(self) -> "EngineBase":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def result(self, *, loader_summary: Optional[dict] = None) -> WalkResult:
        """Assemble the :class:`WalkResult` and close the engine.  Every
        engine reports ``loader_summary`` uniformly — baselines (and any
        engine without a learning-based loader) report ``None``."""
        res = WalkResult(
            num_walks=self.num_walks,
            steps_sampled=self.stats.steps_sampled,
            endpoint_counts=self.endpoint_counts,
            corpus=self.corpus,
            stats=self.stats,
            loader_summary=loader_summary,
            block_store_counters=self.blocks.counters(),
        )
        self.close()
        return res


#: backward-compatible alias — the fixed-shape block pair became the
#: view-stacking ResidentPair
_DeviceBlockPair = ResidentPair
