"""Shared engine plumbing: device block pair, walk pools, stats, advance.

Every out-of-core engine owns

* a :class:`repro.io.WalkPool` (``pool=``, ``"memory"`` or ``"disk"``) — the
  slow tier holding partially-finished walks between time slots; engines
  persist *exclusively* through it;
* a :class:`repro.io.BlockStore` — metered, cached, prefetching access to
  graph blocks; engines load *exclusively* through it;
* a :class:`_DeviceBlockPair` — the two resident block slots as stacked
  device arrays (the "memory" tier of the paper).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import BlockedGraph, ResidentBlock, block_of
from repro.core.stats import SSD, DevicePreset, IOStats
from repro.core.transition import Node2vec, WalkTask
from repro.core.walk import WalkBatch
from repro.io import BlockStore, WalkPool, make_walk_pool

from .step import advance_pair, pow2_pad

__all__ = ["WalkResult", "EngineBase", "_DeviceBlockPair"]


@dataclasses.dataclass
class WalkResult:
    """Task output: endpoint histogram (PPR estimator), optional corpus."""

    num_walks: int
    steps_sampled: int
    endpoint_counts: np.ndarray  # [V] visits at termination
    corpus: Optional[np.ndarray]  # [num_walks, length+1] int32 or None
    stats: IOStats
    loader_summary: Optional[dict] = None
    block_store_counters: Optional[dict] = None

    def ppr_estimate(self) -> np.ndarray:
        tot = max(self.endpoint_counts.sum(), 1)
        return self.endpoint_counts / tot


class _DeviceBlockPair:
    """Two resident block slots as stacked device arrays ("memory")."""

    def __init__(self, bg: BlockedGraph, has_alias: bool):
        self.bg = bg
        self.has_alias = has_alias
        shape_ip = (2, bg.max_block_verts + 1)
        shape_ix = (2, bg.max_block_edges)
        self.start = np.zeros(2, np.int32)
        self.nverts = np.zeros(2, np.int32)
        self.indptr = np.zeros(shape_ip, np.int32)
        self.indices = np.full(shape_ix, -1, np.int32)
        self.alias_j = np.zeros(shape_ix, np.int32)
        self.alias_q = np.ones(shape_ix, np.float32)

    def set_slot(self, s: int, blk: ResidentBlock) -> None:
        self.start[s] = blk.start
        self.nverts[s] = blk.nverts
        self.indptr[s] = blk.indptr
        self.indices[s] = blk.indices
        if self.has_alias and blk.alias_j is not None:
            self.alias_j[s] = blk.alias_j
            self.alias_q[s] = blk.alias_q

    def device_args(self):
        return (
            jnp.asarray(self.start),
            jnp.asarray(self.nverts),
            jnp.asarray(self.indptr),
            jnp.asarray(self.indices),
            jnp.asarray(self.alias_j),
            jnp.asarray(self.alias_q),
        )


class EngineBase:
    """Common state: walk pool ("disk"), block store, stats, bookkeeping."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        k_max: int = 16,
        pool: Union[str, WalkPool] = "memory",
        pool_flush_walks: int = 1 << 18,
        pool_dir: Optional[str] = None,
        prefetch: bool = True,
        block_cache_blocks: int = 4,
        seed: Optional[int] = None,
    ):
        self.bg = bg
        self.task = task
        self.stats = IOStats(preset)
        self.record_walks = record_walks
        self.k_max = k_max if isinstance(task.model, Node2vec) else 1
        if isinstance(task.model, Node2vec) and task.model.p == task.model.q == 1.0:
            self.k_max = 1  # acceptance prob is exactly 1 — no rejection needed
        self.pool_flush_walks = pool_flush_walks
        self.seed = task.seed if seed is None else seed
        self.order = task.model.order
        # backend-neutral surface: works for the in-RAM BlockedGraph and the
        # file-backed repro.io.DiskBlockedGraph alike
        self.has_alias = bg.has_weights
        if self.has_alias:
            bg.ensure_alias()
        self.n_iters = int(np.ceil(np.log2(max(bg.max_block_edges, 2)))) + 2
        self._key = jax.random.PRNGKey(self.seed)
        V = bg.num_vertices
        self.endpoint_counts = np.zeros(V, np.int64)
        src = task.initial_walks(V)
        self.num_walks = src.shape[0]
        self.corpus = (
            np.full((self.num_walks, task.length + 1), -1, np.int32)
            if record_walks
            else None
        )
        if record_walks:
            self.corpus[:, 0] = src
        # the storage layer: walk pool ("disk" tier) + block store
        self.pool: WalkPool = make_walk_pool(
            pool,
            num_blocks=bg.num_blocks,
            stats=self.stats,
            block_starts=bg.block_starts,
            flush_walks=pool_flush_walks,
            directory=pool_dir,
        )
        self.blocks = BlockStore(bg, self.stats, enable_prefetch=prefetch,
                                 capacity=max(block_cache_blocks, 2))
        self._pending_init_src = src
        self.unfinished = self.num_walks
        self.pair = _DeviceBlockPair(bg, self.has_alias)

    # -- pool plumbing ("disk" walk I/O) --------------------------------------
    @property
    def pool_counts(self) -> np.ndarray:
        return self.pool.counts

    @property
    def pool_min_hop(self) -> np.ndarray:
        return self.pool.min_hop

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- termination bookkeeping ----------------------------------------------
    def _retire(self, batch: WalkBatch, wid: np.ndarray, alive: np.ndarray) -> Tuple[WalkBatch, np.ndarray]:
        done = ~alive
        if done.any():
            ends = batch.cur[done]
            np.add.at(self.endpoint_counts, ends, 1)
            self.unfinished -= int(done.sum())
        keep = alive
        return batch.select(keep), wid[keep]

    def _record_trace(self, wid: np.ndarray, trace: np.ndarray) -> None:
        if self.corpus is None or wid.size == 0:
            return
        cols = np.nonzero((trace >= 0).any(axis=0))[0]
        for h in cols:
            col = trace[:, h]
            m = col >= 0
            self.corpus[wid[m], h] = col[m]

    # -- the jitted advance wrapper --------------------------------------------
    def _advance(self, batch: WalkBatch, wid: np.ndarray):
        """Run advance_pair on the resident pair; returns updated host batch."""
        n = len(batch)
        N = pow2_pad(n)
        pad = N - n

        def pad32(x, fill):
            return jnp.asarray(
                np.concatenate([x.astype(np.int32), np.full(pad, fill, np.int32)])
            )

        prev = pad32(batch.prev, 0)
        cur = pad32(batch.cur, 0)
        hop = pad32(batch.hop, 0)
        alive = jnp.asarray(
            np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
        )
        t0 = time.perf_counter()
        out = advance_pair(
            *self.pair.device_args(),
            prev, cur, hop, alive, self._next_key(),
            jnp.int32(self.task.length), jnp.float32(self.task.decay),
            jnp.float32(getattr(self.task.model, "p", 1.0)),
            jnp.float32(getattr(self.task.model, "q", 1.0)),
            order=self.order, k_max=self.k_max, n_iters=self.n_iters,
            record=self.record_walks, has_alias=self.has_alias,
            max_len=int(self.task.length),
        )
        prev_f, cur_f, hop_f, alive_f, steps, trace = jax.tree.map(
            np.asarray, jax.block_until_ready(out)
        )
        self.stats.exec_time += time.perf_counter() - t0
        self.stats.steps_sampled += int(steps)
        if self.record_walks:
            self._record_trace(wid, trace[:n])
        new_batch = WalkBatch(batch.src, prev_f[:n], cur_f[:n], hop_f[:n])
        return new_batch, alive_f[:n]

    # -- initialization stage (paper App. B step 1) -----------------------------
    def _initialize(self) -> None:
        """First-order init: advance walks inside their source block until
        they leave it or terminate, guaranteeing B(u) != B(v) for every
        persisted walk."""
        src = self._pending_init_src
        self._pending_init_src = None
        wid_all = np.arange(src.shape[0], dtype=np.int64)
        src_blocks = block_of(self.bg.block_starts, src)
        uniq = np.unique(src_blocks)
        for k, b in enumerate(uniq):
            blk = self.blocks.get(int(b), sequential=True)
            if k + 1 < len(uniq):
                self.blocks.prefetch(int(uniq[k + 1]))
            self.pair.set_slot(0, blk)
            self.pair.set_slot(1, blk)
            m = src_blocks == b
            batch = WalkBatch(src[m], src[m], src[m], np.zeros(m.sum(), np.int32))
            wid = wid_all[m]
            batch, alive = self._advance(batch, wid)
            batch, wid = self._retire(batch, wid, alive)
            self._persist(batch, wid)

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Release the storage layer: the prefetch thread and any spill
        files/temp dirs a disk pool owns.  Engines are single-run objects;
        ``result()`` calls this, so ``run()`` leaves nothing live behind."""
        self.blocks.close()
        self.pool.close()

    def result(self) -> WalkResult:
        res = WalkResult(
            num_walks=self.num_walks,
            steps_sampled=self.stats.steps_sampled,
            endpoint_counts=self.endpoint_counts,
            corpus=self.corpus,
            stats=self.stats,
            block_store_counters=self.blocks.counters(),
        )
        self.close()
        return res
