"""The jitted pair-advance step shared by every engine.

Vectorised Alg. 2 ``UpdateWalk``: alias/uniform proposal + Node2vec rejection
test with binary-search membership (:mod:`repro.core.sampling`); the Pallas
kernel in :mod:`repro.kernels.node2vec_step` is the TPU version of exactly
this loop.  ``pair_advance_impl`` is the raw function (reused inside
``shard_map`` by :mod:`repro.core.distributed`); ``advance_pair`` the jitted
host entry point.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["pair_advance_impl", "advance_pair", "pow2_pad"]


def pair_advance_impl(
    pair_start,      # [2] i32 — global first-vertex of each resident block
    pair_nverts,     # [2] i32
    indptr,          # [2, MV+1] i32 (block-local offsets)
    indices,         # [2, ME]   i32 (global ids, sorted per row)
    alias_j,         # [2, ME]   i32 (local alias slots; dummy if not has_alias)
    alias_q,         # [2, ME]   f32
    prev,            # [N] i32
    cur,             # [N] i32
    hop,             # [N] i32
    alive,           # [N] bool — not yet terminated
    key,             # PRNG key
    length,          # () i32 — walk length in edges
    decay,           # () f32 — per-step continue probability (1.0 = fixed len)
    p,               # () f32 — node2vec return parameter
    q,               # () f32 — node2vec in-out parameter
    *,
    order: int,
    k_max: int,
    n_iters: int,
    record: bool,
    has_alias: bool,
    max_len: int,
):
    """Advance every walk until it leaves the resident pair or terminates.

    Vectorised Alg. 2 ``UpdateWalk``: "walks keep moving while they jump
    between the two blocks in memory".  Returns
    ``(prev, cur, hop, alive, steps_taken, trace)`` where ``trace[n, h]`` is
    the vertex walk n reached at hop h during this call (-1 = no move).
    """
    N = prev.shape[0]
    ME = indices.shape[1]
    flat_indices = indices.reshape(-1)
    flat_alias_j = alias_j.reshape(-1)
    flat_alias_q = alias_q.reshape(-1)
    max_bias = jnp.maximum(1.0, jnp.maximum(1.0 / p, 1.0 / q))
    # one spare "dump" column (max_len+1) absorbs writes of frozen walks
    trace0 = jnp.full((N, max_len + 2) if record else (1, 1), -1, dtype=jnp.int32)
    iota = jnp.arange(N)

    def in_pair(v):
        return ((v >= pair_start[0]) & (v < pair_start[0] + pair_nverts[0])) | (
            (v >= pair_start[1]) & (v < pair_start[1] + pair_nverts[1])
        )

    def locate(v):
        in0 = (v >= pair_start[0]) & (v < pair_start[0] + pair_nverts[0])
        slot = jnp.where(in0, 0, 1).astype(jnp.int32)
        row = jnp.clip(v - pair_start[slot], 0, indptr.shape[1] - 2)
        return slot, row

    def cond(state):
        _, _, _, _, resident, _, _, _, it = state
        return jnp.any(resident) & (it <= max_len)

    def body(state):
        prev_, cur_, hop_, alive_, resident, key_, steps_, trace_, it = state
        key_, k_prop, k_term = jax.random.split(key_, 3)

        movable = resident  # alive & cur in pair
        slot, row = locate(cur_)
        row_start = indptr[slot, row]
        deg = indptr[slot, row + 1] - row_start
        dead = movable & (deg <= 0)
        movable = movable & (deg > 0)
        deg_c = jnp.maximum(deg, 1)

        if order == 2:
            uslot, urow = locate(prev_)
            u_start = indptr[uslot, urow]
            ulo = uslot * ME + u_start
            uhi = ulo + (indptr[uslot, urow + 1] - u_start)

        # ---- proposal + rejection over k_max rounds -------------------------
        def propose(kk, carry):
            z_, accepted_, key_p = carry
            key_p, k1 = jax.random.split(key_p)
            u123 = jax.random.uniform(k1, (3, N))
            kloc = jnp.minimum((u123[0] * deg_c).astype(jnp.int32), deg_c - 1)
            idx = slot * ME + row_start + kloc
            if has_alias:
                take_alias = u123[1] >= flat_alias_q[idx]
                kloc = jnp.where(take_alias, flat_alias_j[idx], kloc)
                idx = slot * ME + row_start + kloc
            zk = flat_indices[idx]
            if order == 2:
                from repro.core.sampling import searchsorted_rows

                memb = searchsorted_rows(flat_indices, ulo, uhi, zk, n_iters=n_iters)
                bias = jnp.where(zk == prev_, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
                acc_p = bias / max_bias
                acc_p = jnp.where(hop_ == 0, 1.0, acc_p)  # first step: 1st-order
            else:
                acc_p = jnp.ones((N,), jnp.float32)
            last = kk == k_max - 1
            take = (~accepted_) & movable & ((u123[2] < acc_p) | last)
            z_ = jnp.where(take, zk, z_)
            return z_, accepted_ | take, key_p

        z, _, _ = jax.lax.fori_loop(0, k_max, propose, (cur_, ~movable, k_prop))

        # ---- commit ----------------------------------------------------------
        new_hop = hop_ + movable.astype(jnp.int32)
        new_prev = jnp.where(movable, cur_, prev_)
        new_cur = jnp.where(movable, z, cur_)
        finished = movable & (new_hop >= length)
        stopped = movable & (jax.random.uniform(k_term, (N,)) >= decay)
        new_alive = alive_ & ~dead & ~finished & ~stopped
        new_resident = new_alive & in_pair(new_cur)
        if record:
            cols = jnp.where(movable, jnp.clip(new_hop, 0, max_len), max_len + 1)
            trace_ = trace_.at[iota, cols].set(new_cur)
        steps_ = steps_ + movable.astype(jnp.int32).sum()
        return (new_prev, new_cur, new_hop, new_alive, new_resident, key_,
                steps_, trace_, it + 1)

    resident0 = alive & in_pair(cur)
    init = (prev, cur, hop, alive, resident0, key,
            jnp.zeros((), jnp.int32), trace0, jnp.zeros((), jnp.int32))
    prev_f, cur_f, hop_f, alive_f, _, _, steps, trace, _ = jax.lax.while_loop(
        cond, body, init
    )
    if record:
        trace = trace[:, : max_len + 1]
    return prev_f, cur_f, hop_f, alive_f, steps, trace


#: jitted entry point (host engines); the raw impl is reused inside shard_map
advance_pair = partial(
    jax.jit,
    static_argnames=("order", "k_max", "n_iters", "record", "has_alias", "max_len"),
)(pair_advance_impl)


def pow2_pad(n: int, lo: int = 256) -> int:
    """Next power of two >= n (>= lo) — static shapes for the jit cache."""
    m = lo
    while m < n:
        m <<= 1
    return m
