"""The jitted pair-advance step shared by every engine.

Vectorised Alg. 2 ``UpdateWalk`` over a *view pair*: alias/uniform proposal +
Node2vec rejection test with binary-search membership
(:mod:`repro.core.sampling`); the fused Pallas kernel in
:mod:`repro.kernels.pair_advance` is the TPU version of exactly this loop.

Two properties distinguish this implementation from a textbook step loop:

* **Views, not blocks.**  The resident pair is two
  :class:`~repro.core.graph.BlockView`\\ s packed into flat ragged arrays —
  a *full* view (the whole block) or an *activated* view (a compacted CSR
  over only the bucket's activated vertices plus a remap table).  The kernel
  resolves a global vertex to its compact row by binary search over the
  view's sorted ``vids`` remap, so rejection sampling runs directly on the
  compacted arrays and the device footprint of an on-demand bucket is
  ``O(activated vertices)``.  A walk that reaches a vertex with no row in
  the pair simply stops being *resident* (it stays alive); the host engine
  either routes it (it left the block pair) or gathers its row and extends
  the view (a mid-advance extension).

* **Counter-based per-walk RNG.**  Every random draw is keyed by
  ``(base_key, walk_id, hop, round)`` via the hand-rolled threefry folds in
  :mod:`repro.kernels.rng` (bitwise ``jax.random.fold_in`` + ``uniform``) —
  never by call order.  A walk's trajectory is therefore a pure function of the task
  seed and its walk id, independent of batch composition, view shape,
  loading decisions, pause/resume, or which engine advances it.  This is
  what makes {full, ondemand, auto} loading x {ram, disk} graph x
  {memory, disk} pool — and the in-memory oracle — produce bit-identical
  walks.

``pair_advance_impl`` is the raw function (reused inside ``shard_map`` by
:mod:`repro.core.distributed`); ``advance_pair`` the jitted host entry point.
"""

from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import rng

__all__ = [
    "VID_PAD",
    "advance_pair",
    "lower_bound_rows",
    "pair_advance_impl",
    "pow2_pad",
    "remap_search_iters",
]

#: vids padding value — sorts after every real vertex id
VID_PAD = jnp.iinfo(jnp.int32).max


def remap_search_iters(n: int) -> int:
    """Binary-search depth for a remap (``vids``) segment of ``n`` entries —
    the single source of the ``v_iters`` static the kernel consumes."""
    return int(np.ceil(np.log2(max(n, 2)))) + 1


def lower_bound_rows(flat, lo, hi, z, *, n_iters: int):
    """Batched lower bound of ``z`` within the sorted slice ``flat[lo:hi]``.

    Branch-free fixed-iteration binary search (``n_iters`` halvings, like
    :func:`repro.core.sampling.searchsorted_rows` but returning the
    insertion *position*).  Returns ``(pos, found)``.
    """
    lo0 = lo.astype(jnp.int32)
    hi0 = hi.astype(jnp.int32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        val = flat[jnp.clip(mid, 0, flat.shape[0] - 1)]
        valid = lo_ < hi_
        go_right = valid & (val < z)
        lo_ = jnp.where(go_right, mid + 1, lo_)
        hi_ = jnp.where(valid & ~go_right, mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    pos = jnp.clip(lo_f, 0, flat.shape[0] - 1)
    return lo_f, (lo_f < hi0) & (flat[pos] == z)


def pair_advance_impl(
    vids,        # [SV] i32 — both slots' sorted global vertex ids, concatenated
    nverts,      # [2] i32  — valid vids per slot
    vid_base,    # [2] i32  — offset of each slot's segment within vids
    indptr,      # [SP] i32 — concatenated compact local offsets
    ptr_base,    # [2] i32  — offset of each slot's indptr segment
    indices,     # [SE] i32 — concatenated global neighbor ids, sorted per row
    ind_base,    # [2] i32  — offset of each slot's indices segment
    alias_j,     # [SE] i32 — row-local alias slots (dummy if not has_alias)
    alias_q,     # [SE] f32
    wid,         # [N] i32  — walk ids (the per-walk RNG stream identity)
    prev,        # [N] i32
    cur,         # [N] i32
    hop,         # [N] i32
    alive,       # [N] bool — not yet terminated
    key,         # PRNG base key (task seed — NOT split per call)
    length,      # () i32 — walk length in edges
    decay,       # () f32 — per-step continue probability (1.0 = fixed len)
    p,           # () f32 — node2vec return parameter
    q,           # () f32 — node2vec in-out parameter
    *,
    order: int,
    k_max: int,
    n_iters: int,
    v_iters: int,
    record: bool,
    has_alias: bool,
    max_len: int,
):
    """Advance every walk until it leaves the resident view pair or
    terminates.  Returns ``(prev, cur, hop, alive, steps_taken, trace)``
    where ``trace[n, h]`` is the vertex walk n reached at hop h during this
    call (-1 = no move).
    """
    N = prev.shape[0]
    max_bias = jnp.maximum(1.0, jnp.maximum(1.0 / p, 1.0 / q))
    # per-walk streams: fold the walk id in once, the hop/round per draw —
    # all through the shared hand-rolled threefry (repro.kernels.rng), the
    # same primitive the fused Pallas kernel lowers under Mosaic
    kwid = rng.fold_in(*rng.key_halves(key), wid)
    # one spare "dump" column (max_len+1) absorbs writes of frozen walks
    trace0 = jnp.full((N, max_len + 2) if record else (1, 1), -1, dtype=jnp.int32)
    iota = jnp.arange(N)

    def locate(v):
        """Resolve global vertex -> (slot, compact row, found) via the remap."""
        r0, found0 = lower_bound_rows(
            vids,
            jnp.full((N,), vid_base[0]),
            jnp.full((N,), vid_base[0] + nverts[0]),
            v,
            n_iters=v_iters,
        )
        r1, found1 = lower_bound_rows(
            vids,
            jnp.full((N,), vid_base[1]),
            jnp.full((N,), vid_base[1] + nverts[1]),
            v,
            n_iters=v_iters,
        )
        slot = jnp.where(found0, 0, 1).astype(jnp.int32)
        row = jnp.where(found0, r0 - vid_base[0], r1 - vid_base[1])
        row = jnp.clip(row, 0, None)
        return slot, row, found0 | found1

    def cond(state):
        _, _, _, _, resident, _, _, _, _, it = state
        return jnp.any(resident) & (it <= max_len)

    def body(state):
        prev_, cur_, hop_, alive_, resident, slot, row, steps_, trace_, it = state
        # counter-based keys: one stream per (walk id, hop)
        kw0, kw1 = rng.fold_in(*kwid, hop_)

        movable = resident  # alive & cur has a row in the pair
        # (slot, row) for cur_ is carried from the previous iteration's
        # locate(new_cur) — one remap search per hop, not two
        row_start = indptr[ptr_base[slot] + row]
        deg = indptr[ptr_base[slot] + row + 1] - row_start
        dead = movable & (deg <= 0)
        movable = movable & (deg > 0)
        deg_c = jnp.maximum(deg, 1)

        if order == 2:
            uslot, urow, _ = locate(prev_)
            u_start = indptr[ptr_base[uslot] + urow]
            ulo = ind_base[uslot] + u_start
            uhi = ulo + (indptr[ptr_base[uslot] + urow + 1] - u_start)

        # ---- proposal + rejection over k_max rounds -------------------------
        def propose(kk, carry):
            z_, accepted_ = carry
            u123 = rng.uniform3(*rng.fold_in(kw0, kw1, kk))
            kloc = jnp.minimum((u123[0] * deg_c).astype(jnp.int32), deg_c - 1)
            idx = ind_base[slot] + row_start + kloc
            if has_alias:
                take_alias = u123[1] >= alias_q[idx]
                kloc = jnp.where(take_alias, alias_j[idx], kloc)
                idx = ind_base[slot] + row_start + kloc
            zk = indices[idx]
            if order == 2:
                from repro.core.sampling import searchsorted_rows

                memb = searchsorted_rows(indices, ulo, uhi, zk, n_iters=n_iters)
                bias = jnp.where(zk == prev_, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
                acc_p = bias / max_bias
                acc_p = jnp.where(hop_ == 0, 1.0, acc_p)  # first step: 1st-order
            else:
                acc_p = jnp.ones((N,), jnp.float32)
            last = kk == k_max - 1
            take = (~accepted_) & movable & ((u123[2] < acc_p) | last)
            z_ = jnp.where(take, zk, z_)
            return z_, accepted_ | take

        z, _ = jax.lax.fori_loop(0, k_max, propose, (cur_, ~movable))

        # ---- commit ----------------------------------------------------------
        u_term = rng.uniform1(*rng.fold_in(kw0, kw1, k_max))
        new_hop = hop_ + movable.astype(jnp.int32)
        new_prev = jnp.where(movable, cur_, prev_)
        new_cur = jnp.where(movable, z, cur_)
        finished = movable & (new_hop >= length)
        stopped = movable & (u_term >= decay)
        new_alive = alive_ & ~dead & ~finished & ~stopped
        new_slot, new_row, new_found = locate(new_cur)
        new_resident = new_alive & new_found
        if record:
            cols = jnp.where(movable, jnp.clip(new_hop, 0, max_len), max_len + 1)
            trace_ = trace_.at[iota, cols].set(new_cur)
        steps_ = steps_ + movable.astype(jnp.int32).sum()
        return (
            new_prev,
            new_cur,
            new_hop,
            new_alive,
            new_resident,
            new_slot,
            new_row,
            steps_,
            trace_,
            it + 1,
        )

    slot0, row0, found0 = locate(cur)
    resident0 = alive & found0
    init = (
        prev,
        cur,
        hop,
        alive,
        resident0,
        slot0,
        row0,
        jnp.zeros((), jnp.int32),
        trace0,
        jnp.zeros((), jnp.int32),
    )
    prev_f, cur_f, hop_f, alive_f, _, _, _, steps, trace, _ = jax.lax.while_loop(cond, body, init)
    if record:
        trace = trace[:, : max_len + 1]
    return prev_f, cur_f, hop_f, alive_f, steps, trace


#: jitted entry point (host engines); the raw impl is reused inside shard_map
advance_pair = partial(
    jax.jit,
    static_argnames=("order", "k_max", "n_iters", "v_iters", "record", "has_alias", "max_len"),
)(pair_advance_impl)


def pow2_pad(n: int, lo: int = 256) -> int:
    """Next power of two >= n (>= lo) — static shapes for the jit cache."""
    m = lo
    while m < n:
        m <<= 1
    return m
