"""GraSorw: the bi-block engine (the paper's system).

Triangular bi-block scheduling (§4.2), skewed walk storage + bucket
management (§4.3), bucket-extending (Alg. 2), learning-based block loading
(§5).  Block *views* come in through the :class:`repro.io.BlockStore`: a
full-load decision materialises the whole ancillary block, an on-demand
decision builds a compacted *activated* :class:`~repro.core.graph.BlockView`
over only the bucket's prev/cur vertices — and execution runs on that view,
so the device footprint of an on-demand bucket is ``O(activated vertices)``
(``IOStats.peak_resident_bytes`` is the gauge).  Walks that reach a
non-activated vertex mid-advance pause; their rows are gathered and
*appended* to the view (never a re-materialisation) and the advance
resumes.  The triangular schedule knows the next ancillary bucket before
the current one finishes, so the store prefetches its view — full or
partial — under the jitted advance call.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.buckets import split_into_buckets
from repro.core.graph import BlockedGraph, BlockView, block_of
from repro.core.loader import BlockLoadingModel
from repro.core.stats import SSD, DevicePreset
from repro.core.transition import WalkTask
from repro.core.walk import WalkBatch

from .base import EngineBase, WalkResult

__all__ = ["BiBlockEngine"]


class BiBlockEngine(EngineBase):
    """Triangular bi-block scheduling + skewed storage + buckets + LBL."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        loading: str = "auto",
        bucket_extending: bool = True,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.loader = BlockLoadingModel(bg.num_blocks, mode=loading)
        self.bucket_extending = bucket_extending

    # skewed storage: persist with min(B(u), B(v)); first-order models never
    # read prev, so they use the traditional B(cur) association (§7.8)
    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        if self.order == 1:
            assoc = block_of(self.bg.block_starts, batch.cur)
        else:
            assoc = np.minimum(
                block_of(self.bg.block_starts, batch.prev),
                block_of(self.bg.block_starts, batch.cur),
            )
        for b in np.unique(assoc):
            m = assoc == b
            self.pool.push(int(b), batch.select(m), wid[m])

    #: modelled in-memory cost per sampled step (feeds the LR exec component)
    STEP_COST = 2.0e-8

    @staticmethod
    def _bucket_activated(bucket: WalkBatch, s: int, e: int) -> np.ndarray:
        """Activated vertices of a bucket within block range [s, e)."""
        act = np.concatenate([bucket.prev, bucket.cur])
        return act[(act >= s) & (act < e)]

    def _load_ancillary(
        self,
        i: int,
        n_bucket_walks: int,
        activated: np.ndarray,
    ) -> Tuple[str, float, float, BlockView]:
        """Load block ``i`` with the learned method; meter; return
        (decision, eta, load_cost, view) — execution cost is added before
        feeding the model (the paper's t_f / t_o cover loading *and*
        executing, §5.2.1)."""
        nv = int(self.bg.block_nverts[i])
        decision = self.loader.choose(i, n_bucket_walks, nv)
        eta = n_bucket_walks / max(nv, 1)
        if decision == "full":
            nbytes = 4 * (nv + 1) + 4 * int(self.bg.block_nedges[i])
            cost = self.stats.preset.seq_cost(nbytes)
            view = self.blocks.get_view(i, sequential=True)
        else:
            view = self.blocks.partial_view(i, activated)
            nbytes = self.bg.activated_load_bytes(activated)
            n_act = view.nverts
            cost = self.stats.preset.rand_cost(n_act, nbytes)
            self.stats.ondemand_load(n_act, nbytes)
        return decision, eta, cost, view

    def _prefetch_bucket(self, i: int, bucket: WalkBatch, n_walks: int) -> None:
        """Overlap the next bucket's view build with this bucket's advance.
        The tentative decision mirrors :meth:`_load_ancillary`'s (``choose``
        is pure); a mismatch — or a bucket grown by Alg. 2 extension in the
        meantime — just misses the prefetch cache and builds synchronously.
        """
        nv = int(self.bg.block_nverts[i])
        if self.loader.choose(i, n_walks, nv) == "full":
            self.blocks.prefetch(i)
        else:
            s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
            self.blocks.prefetch_partial(i, self._bucket_activated(bucket, s, e))

    def _advance_on_view(
        self,
        i: int,
        bucket: WalkBatch,
        bwid: np.ndarray,
        view: BlockView,
        decision: str,
    ) -> Tuple[WalkBatch, np.ndarray, float]:
        """Advance the bucket on the resident pair until every walk left it
        or terminated.  On an activated view, walks that reach a
        non-activated vertex of block ``i`` pause mid-advance; their rows
        are gathered (on-demand vertex I/O), *appended* to the view, and
        the advance resumes — the whole block is never materialised.
        Returns (batch, alive, extension_cost)."""
        cost = 0.0
        batch, alive = self._advance(bucket, bwid)
        if decision != "ondemand":
            return batch, alive, cost
        s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
        while True:
            stuck = alive & (batch.cur >= s) & (batch.cur < e)
            if not stuck.any():
                break
            pending = np.unique(batch.cur[stuck])
            ext = pending[~view.has_vertices(pending)]
            if ext.size == 0:
                break
            nbytes = self.bg.activated_load_bytes(ext)
            self.stats.ondemand_load(ext.size, nbytes)
            cost += self.stats.preset.rand_cost(ext.size, nbytes)
            # first-order buckets alias the same view in both slots — keep
            # the pair deduped so the extended rows are stored once
            both = self.pair.views[0] is self.pair.views[1]
            view = self.blocks.extend_view(view, ext)
            if both:
                self.pair.set_slot(0, view)
            self.pair.set_slot(1, view)
            batch, alive = self._advance(batch, bwid, alive)
        return batch, alive, cost

    def _run(self) -> WalkResult:
        if self.order == 1:
            return self._run_first_order()
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB - 1):
                if self.pool.counts[b] == 0:
                    continue
                batch, wid = self.pool.load(b)
                self.stats.time_slots += 1
                cur_view = self.blocks.get_view(b, sequential=True)
                self.pair.set_slot(0, cur_view)
                # wid-aligned buckets: pending maps bucket id -> (batch, wid)
                pending: Dict[int, Tuple[WalkBatch, np.ndarray]] = split_into_buckets(
                    self.bg.block_starts, batch, b, wid
                )
                i = b  # ancillary cursor: strictly increasing (triangular)
                while True:
                    remaining = sorted(k for k in pending if k > i)
                    if not remaining:
                        break
                    i = remaining[0]
                    # the schedule already knows the next ancillary bucket:
                    # overlap its view build with this bucket's advance
                    if len(remaining) > 1:
                        nxt = remaining[1]
                        nxt_bucket, _ = pending[nxt]
                        self._prefetch_bucket(nxt, nxt_bucket, len(nxt_bucket))
                    bucket, bwid = pending.pop(i)
                    self.stats.bucket_executions += 1
                    s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
                    activated = self._bucket_activated(bucket, s, e)
                    decision, eta, cost, view = self._load_ancillary(i, len(bucket), activated)
                    self.pair.set_slot(1, view)
                    steps_before = self.stats.steps_sampled
                    bucket, alive, ext_cost = self._advance_on_view(i, bucket, bwid, view, decision)
                    cost += ext_cost
                    cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                    self.loader.observe(i, eta, cost, decision)
                    bucket, bwid = self._retire(bucket, bwid, alive)
                    if len(bucket) == 0:
                        continue
                    # Alg. 2 routing
                    pre_blk = block_of(self.bg.block_starts, bucket.prev)
                    cur_blk = block_of(self.bg.block_starts, bucket.cur)
                    extend = (
                        (cur_blk > i) & (pre_blk == b)
                        if self.bucket_extending
                        else np.zeros(len(bucket), bool)
                    )
                    # persist the non-extending walks with min-rule
                    self._persist(bucket.select(~extend), bwid[~extend])
                    if extend.any():
                        ext_batch = bucket.select(extend)
                        ext_wid = bwid[extend]
                        for nb in np.unique(cur_blk[extend]):
                            m = cur_blk[extend] == nb
                            nb = int(nb)
                            if nb in pending:
                                pb, pw = pending[nb]
                                pending[nb] = (
                                    WalkBatch.concat([pb, ext_batch.select(m)]),
                                    np.concatenate([pw, ext_wid[m]]),
                                )
                            else:
                                pending[nb] = (ext_batch.select(m), ext_wid[m])
        return self.result(loader_summary=self.loader.summary())

    def _run_first_order(self) -> WalkResult:
        """§7.8: first-order walks need only the current block; iteration
        scheduling + the learning-based loader on the current block itself
        ("heavy block loads become light vertex I/Os once few walks remain").
        Both slots hold the *same* view — an on-demand slot is a compacted
        view over just the walks' current vertices."""
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB):
                if self.pool.counts[b] == 0:
                    continue
                batch, wid = self.pool.load(b)
                self.stats.time_slots += 1
                self.stats.bucket_executions += 1
                activated = batch.cur
                decision, eta, cost, view = self._load_ancillary(b, len(batch), activated)
                self.pair.set_slot(0, view)
                self.pair.set_slot(1, view)
                # iteration order makes the next current block predictable
                nxt = next((j for j in range(b + 1, NB) if self.pool.counts[j] > 0), None)
                if nxt is not None:
                    self.blocks.prefetch(nxt)
                steps_before = self.stats.steps_sampled
                batch, alive, ext_cost = self._advance_on_view(b, batch, wid, view, decision)
                cost += ext_cost
                cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                self.loader.observe(b, eta, cost, decision)
                batch, wid = self._retire(batch, wid, alive)
                self._persist(batch, wid)
        return self.result(loader_summary=self.loader.summary())
