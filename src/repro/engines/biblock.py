"""GraSorw: the bi-block engine (the paper's system).

Triangular bi-block scheduling (§4.2), skewed walk storage + bucket
management (§4.3), bucket-extending (Alg. 2), learning-based block loading
(§5).  Blocks come in through the :class:`repro.io.BlockStore` — the
triangular schedule knows the next ancillary block before the current bucket
finishes, so the store prefetches it under the jitted advance call.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.buckets import split_into_buckets
from repro.core.graph import BlockedGraph, block_of
from repro.core.loader import BlockLoadingModel
from repro.core.stats import SSD, DevicePreset
from repro.core.transition import WalkTask
from repro.core.walk import WalkBatch

from .base import EngineBase, WalkResult

__all__ = ["BiBlockEngine"]


class BiBlockEngine(EngineBase):
    """Triangular bi-block scheduling + skewed storage + buckets + LBL."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        loading: str = "auto",
        bucket_extending: bool = True,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.loader = BlockLoadingModel(bg.num_blocks, mode=loading)
        self.bucket_extending = bucket_extending

    # skewed storage: persist with min(B(u), B(v)); first-order models never
    # read prev, so they use the traditional B(cur) association (§7.8)
    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        if self.order == 1:
            assoc = block_of(self.bg.block_starts, batch.cur)
        else:
            assoc = np.minimum(
                block_of(self.bg.block_starts, batch.prev),
                block_of(self.bg.block_starts, batch.cur),
            )
        for b in np.unique(assoc):
            m = assoc == b
            self.pool.push(int(b), batch.select(m), wid[m])

    #: modelled in-memory cost per sampled step (feeds the LR exec component)
    STEP_COST = 2.0e-8

    def _load_ancillary(self, i: int, n_bucket_walks: int, activated: np.ndarray):
        """Load block i with the learned method; meter; return (decision,
        eta, load_cost) — execution cost is added before feeding the model
        (the paper's t_f / t_o cover loading *and* executing, §5.2.1)."""
        blk = self.blocks.get(i, charge=False)
        nv = int(self.bg.block_nverts[i])
        decision = self.loader.choose(i, n_bucket_walks, nv)
        eta = n_bucket_walks / max(nv, 1)
        if decision == "full":
            nbytes = blk.nbytes_full()
            cost = self.stats.preset.seq_cost(nbytes)
            self.stats.block_load(i, nbytes, sequential=True)
        else:
            nbytes = self.bg.activated_load_bytes(activated)
            n_act = np.unique(activated).size
            cost = self.stats.preset.rand_cost(n_act, nbytes)
            self.stats.ondemand_load(n_act, nbytes)
        self.pair.set_slot(1, blk)
        return decision, eta, cost

    def _meter_extension(self, i: int, batch_before: WalkBatch, batch_after: WalkBatch) -> float:
        """On-demand loads gather extension vertices reached mid-advance.
        Returns the modelled cost of those gathers."""
        s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
        touched = batch_after.cur[(batch_after.cur >= s) & (batch_after.cur < e)]
        pre = np.unique(
            np.concatenate(
                [
                    batch_before.cur[(batch_before.cur >= s) & (batch_before.cur < e)],
                    batch_before.prev[(batch_before.prev >= s) & (batch_before.prev < e)],
                ]
            )
        )
        ext = np.setdiff1d(np.unique(touched), pre, assume_unique=False)
        if ext.size:
            nbytes = self.bg.activated_load_bytes(ext)
            self.stats.ondemand_load(ext.size, nbytes)
            return self.stats.preset.rand_cost(ext.size, nbytes)
        return 0.0

    def run(self) -> WalkResult:
        if self.order == 1:
            return self._run_first_order()
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB - 1):
                if self.pool.counts[b] == 0:
                    continue
                batch, wid = self.pool.load(b)
                self.stats.time_slots += 1
                blk_b = self.blocks.get(b, sequential=True)
                self.pair.set_slot(0, blk_b)
                # wid-aligned buckets: pending maps bucket id -> (batch, wid)
                pending: Dict[int, Tuple[WalkBatch, np.ndarray]] = (
                    split_into_buckets(self.bg.block_starts, batch, b, wid)
                )
                i = b  # ancillary cursor: strictly increasing (triangular)
                while True:
                    remaining = sorted(k for k in pending if k > i)
                    if not remaining:
                        break
                    i = remaining[0]
                    # the schedule already knows the next ancillary block:
                    # overlap its materialisation with this bucket's advance
                    if len(remaining) > 1:
                        self.blocks.prefetch(remaining[1])
                    bucket, bwid = pending.pop(i)
                    self.stats.bucket_executions += 1
                    activated = np.concatenate([bucket.prev, bucket.cur])
                    s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
                    activated = activated[(activated >= s) & (activated < e)]
                    decision, eta, cost = self._load_ancillary(i, len(bucket), activated)
                    before = bucket
                    steps_before = self.stats.steps_sampled
                    bucket, alive = self._advance(bucket, bwid)
                    if decision == "ondemand":
                        cost += self._meter_extension(i, before, bucket)
                    cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                    self.loader.observe(i, eta, cost, decision)
                    bucket, bwid = self._retire(bucket, bwid, alive)
                    if len(bucket) == 0:
                        continue
                    # Alg. 2 routing
                    pre_blk = block_of(self.bg.block_starts, bucket.prev)
                    cur_blk = block_of(self.bg.block_starts, bucket.cur)
                    extend = (
                        (cur_blk > i) & (pre_blk == b)
                        if self.bucket_extending
                        else np.zeros(len(bucket), bool)
                    )
                    # persist the non-extending walks with min-rule
                    self._persist(bucket.select(~extend), bwid[~extend])
                    if extend.any():
                        ext_batch = bucket.select(extend)
                        ext_wid = bwid[extend]
                        for nb in np.unique(cur_blk[extend]):
                            m = cur_blk[extend] == nb
                            nb = int(nb)
                            if nb in pending:
                                pb, pw = pending[nb]
                                pending[nb] = (
                                    WalkBatch.concat([pb, ext_batch.select(m)]),
                                    np.concatenate([pw, ext_wid[m]]),
                                )
                            else:
                                pending[nb] = (ext_batch.select(m), ext_wid[m])
        res = self.result()
        res.loader_summary = self.loader.summary()
        return res

    def _run_first_order(self) -> WalkResult:
        """§7.8: first-order walks need only the current block; iteration
        scheduling + the learning-based loader on the current block itself
        ("heavy block loads become light vertex I/Os once few walks remain")."""
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB):
                if self.pool.counts[b] == 0:
                    continue
                batch, wid = self.pool.load(b)
                self.stats.time_slots += 1
                self.stats.bucket_executions += 1
                activated = batch.cur
                decision, eta, cost = self._load_ancillary(b, len(batch), activated)
                self.pair.set_slot(0, self.blocks.get(b, charge=False))
                # iteration order makes the next current block predictable
                nxt = next((j for j in range(b + 1, NB) if self.pool.counts[j] > 0), None)
                if nxt is not None:
                    self.blocks.prefetch(nxt)
                before = batch
                steps_before = self.stats.steps_sampled
                batch, alive = self._advance(batch, wid)
                if decision == "ondemand":
                    cost += self._meter_extension(b, before, batch)
                cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                self.loader.observe(b, eta, cost, decision)
                batch, wid = self._retire(batch, wid, alive)
                self._persist(batch, wid)
        res = self.result()
        res.loader_summary = self.loader.summary()
        return res
