"""GraSorw: the bi-block engine (the paper's system).

Triangular bi-block scheduling (§4.2), skewed walk storage + bucket
management (§4.3), bucket-extending (Alg. 2), learning-based block loading
(§5).  Block *views* come in through the :class:`repro.io.BlockStore`: a
full-load decision materialises the whole ancillary block, an on-demand
decision builds a compacted *activated* :class:`~repro.core.graph.BlockView`
over only the bucket's prev/cur vertices — and execution runs on that view,
so the device footprint of an on-demand bucket is ``O(activated vertices)``
(``IOStats.peak_resident_bytes`` is the gauge).  Walks that reach a
non-activated vertex mid-advance pause; their rows are gathered and
*appended* to the view (never a re-materialisation) and the advance
resumes.

Since the staged pipeline refactor the run is organised by a
:class:`~repro.core.scheduler.TimeSlotPlan` and a
:class:`~repro.engines.pipeline.BucketPipeline`: while one bucket advances
on the device, the walk-pool writer thread applies persists and drains +
splits the *next* slot's pool, and the block-store prefetch thread builds
the next slot's current view and the next bucket's ancillary view.  With
``async_pipeline=False`` (the serial reference mode) every stage runs
inline; the counter-based per-walk RNG makes the two modes bit-identical.

The engine is also the execution tier of the query-serving front end
(:mod:`repro.serve`): an admission batch of point queries becomes one run
with its concatenated walk sources injected via ``initial_walks``, a
shared ``block_store`` (hot-set pinned) + ``stats``, and an ``on_retire``
hook attributing each terminating walk's endpoint back to its query — all
:class:`~repro.engines.base.EngineBase` seams, so serving rides the exact
triangular sweep (and bit-exact walks) of a batch run.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.buckets import push_by_block_assignment
from repro.core.graph import BlockedGraph, BlockView, block_of
from repro.core.loader import BlockLoadingModel
from repro.core.scheduler import TimeSlotPlan
from repro.core.stats import SSD, DevicePreset
from repro.core.transition import WalkTask
from repro.core.walk import WalkBatch

from .base import EngineBase, WalkResult
from .pipeline import BucketCursor, BucketPipeline

__all__ = ["BiBlockEngine"]


class BiBlockEngine(EngineBase):
    """Triangular bi-block scheduling + skewed storage + buckets + LBL."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        loading: str = "auto",
        bucket_extending: bool = True,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        async_pipeline: bool = True,
        writer_queue: int = 64,
        **kw,
    ):
        super().__init__(
            bg,
            task,
            preset=preset,
            record_walks=record_walks,
            async_pipeline=async_pipeline,
            writer_queue=writer_queue,
            **kw,
        )
        self.loader = BlockLoadingModel(bg.num_blocks, mode=loading)
        self.bucket_extending = bucket_extending

    # skewed storage: persist with min(B(u), B(v)); first-order models never
    # read prev, so they use the traditional B(cur) association (§7.8)
    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        push_by_block_assignment(self.pool, self.bg.block_starts, self.order, batch, wid)

    #: modelled in-memory cost per sampled step (feeds the LR exec component)
    STEP_COST = 2.0e-8

    @staticmethod
    def _bucket_activated(bucket: WalkBatch, s: int, e: int) -> np.ndarray:
        """Activated vertices of a bucket within block range [s, e)."""
        act = np.concatenate([bucket.prev, bucket.cur])
        return act[(act >= s) & (act < e)]

    def _load_ancillary(
        self,
        i: int,
        n_bucket_walks: int,
        activated: np.ndarray,
    ) -> Tuple[str, float, float, BlockView]:
        """Load block ``i`` with the learned method; meter; return
        (decision, eta, load_cost, view) — execution cost is added before
        feeding the model (the paper's t_f / t_o cover loading *and*
        executing, §5.2.1)."""
        nv = int(self.bg.block_nverts[i])
        decision = self.loader.choose(i, n_bucket_walks, nv)
        eta = n_bucket_walks / max(nv, 1)
        if decision == "full":
            nbytes = 4 * (nv + 1) + 4 * int(self.bg.block_nedges[i])
            cost = self.stats.preset.seq_cost(nbytes)
            view = self.blocks.get_view(i, sequential=True)
        else:
            gap = int(getattr(self.bg, "io_coalesce_gap", 0))
            sys0 = self.stats.ondemand_syscalls
            waste0 = self.stats.coalesce_waste_bytes
            view = self.blocks.partial_view(i, activated)
            nbytes = self.bg.activated_load_bytes(activated)
            n_act = view.nverts
            # with the planner on, cost follows the coalesced ranges the
            # store just gauged, not the raw vertex count (per-seek term)
            seeks = self.stats.ondemand_syscalls - sys0 if gap > 0 else None
            waste = self.stats.coalesce_waste_bytes - waste0 if gap > 0 else 0
            cost = self.loader.ondemand_cost(
                self.stats.preset, n_act, nbytes, seeks=seeks, waste_bytes=waste
            )
            self.stats.ondemand_load(n_act, nbytes, seeks=seeks, waste_bytes=waste)
        return decision, eta, cost, view

    def _schedule_bucket_view(self, i: int, bucket: WalkBatch) -> None:
        """Overlap the next bucket's view build with this bucket's advance.
        The tentative decision mirrors :meth:`_load_ancillary`'s (``choose``
        is pure); a mismatch — or a bucket grown by Alg. 2 extension in the
        meantime — just misses the prefetch cache and builds synchronously.
        """
        nv = int(self.bg.block_nverts[i])
        if self.loader.choose(i, len(bucket), nv) == "full":
            self.blocks.schedule([("full", i)])
        else:
            s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
            self.blocks.schedule([("partial", i, self._bucket_activated(bucket, s, e))])

    def _advance_on_view(
        self,
        i: int,
        bucket: WalkBatch,
        bwid: np.ndarray,
        view: BlockView,
        decision: str,
    ) -> Tuple[WalkBatch, np.ndarray, float]:
        """Advance the bucket on the resident pair until every walk left it
        or terminated.  On an activated view, walks that reach a
        non-activated vertex of block ``i`` pause mid-advance; their rows
        are gathered (on-demand vertex I/O), *appended* to the view, and
        the advance resumes — the whole block is never materialised.
        Returns (batch, alive, extension_cost)."""
        cost = 0.0
        batch, alive = self._advance(bucket, bwid)
        if decision != "ondemand":
            return batch, alive, cost
        s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
        while True:
            stuck = alive & (batch.cur >= s) & (batch.cur < e)
            if not stuck.any():
                break
            pending = np.unique(batch.cur[stuck])
            ext = pending[~view.has_vertices(pending)]
            if ext.size == 0:
                break
            nbytes = self.bg.activated_load_bytes(ext)
            gap = int(getattr(self.bg, "io_coalesce_gap", 0))
            sys0 = self.stats.ondemand_syscalls
            waste0 = self.stats.coalesce_waste_bytes
            # first-order buckets alias the same view in both slots — keep
            # the pair deduped so the extended rows are stored once
            both = self.pair.views[0] is self.pair.views[1]
            view = self.blocks.extend_view(view, ext)
            seeks = self.stats.ondemand_syscalls - sys0 if gap > 0 else None
            waste = self.stats.coalesce_waste_bytes - waste0 if gap > 0 else 0
            self.stats.ondemand_load(ext.size, nbytes, seeks=seeks, waste_bytes=waste)
            cost += self.loader.ondemand_cost(
                self.stats.preset, ext.size, nbytes, seeks=seeks, waste_bytes=waste
            )
            if both:
                self.pair.set_slot(0, view)
            self.pair.set_slot(1, view)
            batch, alive = self._advance(batch, bwid, alive)
        return batch, alive, cost

    def _run(self) -> WalkResult:
        """The staged slot loop, shared by first- and second-order tasks:
        the :class:`TimeSlotPlan` names the slots, the
        :class:`BucketPipeline` overlaps the next slot's pool drain + bucket
        split and the next views with the current advance (or runs
        everything inline when ``async_pipeline=False``)."""
        self._initialize()
        plan = TimeSlotPlan(self.bg.num_blocks, self.order)
        pipe = BucketPipeline(
            pool=self.pool,
            blocks=self.blocks,
            block_starts=self.bg.block_starts,
            stats=self.stats,
            plan=plan,
            enabled=self.async_pipeline,
        )
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * self.bg.num_blocks + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in plan.slots():
                if not pipe.slot_has_walks(b):
                    continue
                self.stats.time_slots += 1
                if self.order == 1:
                    self._run_slot_first_order(b, pipe)
                else:
                    self._run_slot(b, pipe)
        pipe.finish()
        return self.result(loader_summary=self.loader.summary())

    def _run_slot(self, b: int, pipe: BucketPipeline) -> None:
        """One second-order time slot: current block ``b`` resident in slot
        0, ancillary buckets through the ordered cursor in slot 1."""
        cursor: BucketCursor = pipe.acquire_slot(b)
        pipe.preload_slot(pipe.plan_next(b))
        cur_view = self.blocks.get_view(b, sequential=True)
        self.pair.set_slot(0, cur_view)
        while True:
            item = cursor.pop()
            if item is None:
                break
            i, bucket, bwid = item
            # the schedule already knows the next ancillary bucket:
            # overlap its view build with this bucket's advance
            nxt = cursor.peek()
            if nxt is not None:
                self._schedule_bucket_view(nxt, cursor.get(nxt)[0])
            self.stats.bucket_executions += 1
            s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
            activated = self._bucket_activated(bucket, s, e)
            decision, eta, cost, view = self._load_ancillary(i, len(bucket), activated)
            self.pair.set_slot(1, view)
            steps_before = self.stats.steps_sampled
            bucket, alive, ext_cost = self._advance_on_view(i, bucket, bwid, view, decision)
            cost += ext_cost
            cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
            self.loader.observe(i, eta, cost, decision)
            bucket, bwid = self._retire(bucket, bwid, alive)
            if len(bucket) == 0:
                continue
            # Alg. 2 routing
            pre_blk = block_of(self.bg.block_starts, bucket.prev)
            cur_blk = block_of(self.bg.block_starts, bucket.cur)
            extend = (
                (cur_blk > i) & (pre_blk == b)
                if self.bucket_extending
                else np.zeros(len(bucket), bool)
            )
            # persist the non-extending walks with min-rule
            self._persist(bucket.select(~extend), bwid[~extend])
            if extend.any():
                ext_batch = bucket.select(extend)
                ext_wid = bwid[extend]
                ext_blk = cur_blk[extend]
                for nb in np.unique(ext_blk):
                    m = ext_blk == nb
                    cursor.add(int(nb), ext_batch.select(m), ext_wid[m])

    def _run_slot_first_order(self, b: int, pipe: BucketPipeline) -> None:
        """§7.8: first-order walks need only the current block; iteration
        scheduling + the learning-based loader on the current block itself
        ("heavy block loads become light vertex I/Os once few walks remain").
        Both slots hold the *same* view — an on-demand slot is a compacted
        view over just the walks' current vertices."""
        batch, wid = pipe.acquire_slot(b)
        pipe.preload_slot(pipe.plan_next(b))
        self.stats.bucket_executions += 1
        decision, eta, cost, view = self._load_ancillary(b, len(batch), batch.cur)
        self.pair.set_slot(0, view)
        self.pair.set_slot(1, view)
        steps_before = self.stats.steps_sampled
        batch, alive, ext_cost = self._advance_on_view(b, batch, wid, view, decision)
        cost += ext_cost
        cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
        self.loader.observe(b, eta, cost, decision)
        batch, wid = self._retire(batch, wid, alive)
        self._persist(batch, wid)
