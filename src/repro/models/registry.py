"""Model facade: one entry point per model kind (decoder LM, VLM-prefixed
LM, encoder-decoder), dispatched from the config.  The launch/ and train/
layers only ever talk to these four functions + `init_params_shape`.

Batch schema (input_specs() in launch/dryrun.py produces exactly these):
  LM     : {tokens [B,S] i32, labels [B,S] i32}
  VLM    : + prefix [B,P,D] bf16       (stub frontend output)
  audio  : {frames [B,Se,D] bf16, tokens [B,Sd] i32, labels [B,Sd] i32}
  decode : {token [B,1] i32, cache_len [] i32} + caches pytree
"""

from __future__ import annotations

from typing import Any, Dict

import jax

from . import encdec, transformer
from .common import ModelConfig

__all__ = [
    "model_init",
    "model_forward",
    "model_prefill",
    "model_decode",
    "model_caches",
    "init_params_shape",
]


def model_init(key, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_init(key, cfg)
    return transformer.init_params(key, cfg)


def init_params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))


def model_forward(params, batch: Dict[str, Any], cfg: ModelConfig):
    """Teacher-forced logits over the *label-aligned* region + aux loss."""
    if cfg.is_encoder_decoder:
        logits, aux = encdec.encdec_forward(
            params, batch["frames"], batch["tokens"], cfg
        )
        return logits, aux
    prefix = batch.get("prefix")
    logits, aux = transformer.forward(params, batch["tokens"], cfg,
                                      prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1] :]  # labels align with tokens
    return logits, aux


def model_prefill(params, batch: Dict[str, Any], cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_prefill(params, batch["frames"], batch["tokens"], cfg)
    return transformer.prefill(
        params, batch["tokens"], cfg, prefix_embeds=batch.get("prefix")
    )


def model_caches(cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 0):
    if cfg.is_encoder_decoder:
        return encdec.init_decoder_caches(cfg, batch, max_len, enc_len or max_len)
    return transformer.init_caches(cfg, batch, max_len)


def model_decode(params, token, caches, cache_len, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_decode_step(params, token, caches, cache_len, cfg)
    return transformer.decode_step(params, token, caches, cache_len, cfg)
