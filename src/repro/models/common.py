"""Model substrate: config schema, norms, embeddings, RoPE, MLPs, init.

One :class:`ModelConfig` describes every assigned architecture; the layer
stack is expressed as *segments* — ``(pattern, n_groups)`` pairs where
``pattern`` is a tuple of block kinds (e.g. ``('rglru','rglru','local')``)
scanned ``n_groups`` times with stacked parameters.  Homogeneous models are
the special case ``((kind,), n_layers)``.  This keeps HLO size O(1) in depth
(compile time on the 512-device dry-run) while supporting hybrids.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ModelConfig",
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "dense_init",
    "mlp_apply",
    "mlp_init",
    "padded_vocab",
]

BlockKind = str  # 'attn' | 'local' | 'mla' | 'ssd' | 'rglru' | 'enc' | 'dec'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Tuple[Tuple[str, ...], int], ...]  # ((pattern), n_groups)
    # attention
    window: Optional[int] = None  # sliding window for 'local' blocks / SWA
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # mlp
    mlp_type: str = "swiglu"  # 'swiglu' | 'geglu' | 'gelu'
    # MoE (0 experts = dense)
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    moe_shard_experts: bool = False  # EP when n_experts % model axis == 0
    #: store each expert's gated FFN as `split` column-sliced *virtual
    #: experts* (exact for gated MLPs).  Lets an expert count smaller than
    #: the model axis use expert parallelism (mixtral: 8 experts x split 2
    #: = 16 virtual experts on the 16-way axis) with no runtime transpose.
    moe_virtual_split: int = 1
    capacity_factor: float = 1.25
    # MLA
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    # RG-LRU
    lru_width: int = 0
    # enc-dec
    n_encoder_layers: int = 0
    learned_pos: bool = False
    max_pos: int = 0  # learned-position table size (enc-dec)
    # frontend stubs
    frontend: Optional[str] = None  # 'vision' | 'audio' | None
    num_prefix: int = 0  # patch embeddings prepended ([vlm])
    # numerics
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    #: activation rematerialisation for the layer scan:
    #: 'none' | 'nothing' (recompute everything) | 'dots' (save matmul outs)
    remat_policy: str = "nothing"
    #: gradient-accumulation microbatches for train_step (activation memory
    #: divides by this; global batch and numerics are unchanged)
    train_microbatches: int = 1
    # serve-ability flags
    subquadratic: bool = False  # may run long_500k
    skip_decode: bool = False  # encoder-only archs

    # ----- derived -----------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        out = []
        for pattern, n in self.segments:
            out.extend(list(pattern) * n)
        return tuple(out)

    @property
    def vocab_padded(self) -> int:
        return padded_vocab(self.vocab_size)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameter count (exact, from the init shapes)."""
        from .registry import init_params_shape  # local: avoid cycle

        shapes = init_params_shape(self)
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))

    def active_param_count(self) -> int:
        """Active-per-token params (MoE counts top_k + shared experts)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        from .registry import init_params_shape

        shapes = init_params_shape(self)
        moe_total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = "/".join(str(k) for k in path)
            if "experts" in keys and "shared" not in keys:
                moe_total += int(np.prod(leaf.shape))
        active_moe = moe_total * self.top_k // max(self.n_experts, 1)
        return total - moe_total + active_moe


def padded_vocab(v: int, multiple: int = 256) -> int:
    """Vocab padded for clean sharding over the 16-way model axis."""
    return int(math.ceil(v / multiple) * multiple)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(positions, dim: int, theta: float):
    """Rotary tables: returns (sin, cos) of shape [..., dim/2]."""
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: [..., S, H, D]; sin/cos: [..., S, D/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# init + dense MLPs
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    return {
        "w_in": dense_init(k1, (d, 2 * f if gated else f), cfg.dtype),
        "w_out": dense_init(k2, (f, d), cfg.dtype),
    }


def mlp_apply(params, x, mlp_type: str):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if mlp_type in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])
