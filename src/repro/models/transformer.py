"""Decoder-only LM assembly over heterogeneous layer segments.

A config's ``segments`` is a tuple of ``(pattern, n_groups)``; each pattern
entry is ``"<block>[+<mlp>]"`` with block in {attn, local, mla, ssd, rglru}
and mlp in {mlp, moe}.  Parameters of a segment are stacked on a leading
group axis and applied with `lax.scan` — HLO stays O(segment count), not
O(depth), which is what keeps the 512-device dry-run compile times sane.

The same assembly serves:
  * ``forward``      — teacher-forced logits (train / eval / VLM prefix)
  * ``prefill``      — forward + per-layer caches + last-position logits
  * ``decode_step``  — one token against the caches (serve_step)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attention_apply,
    attention_decode,
    attn_init,
    init_kv_cache,
)
from .common import ModelConfig, dense_init, mlp_apply, mlp_init, rms_norm
from repro.sharding.context import constrain
from .mla import init_mla_cache, mla_apply, mla_decode, mla_init
from .moe import moe_apply, moe_init
from .rglru import init_rglru_cache, rglru_apply, rglru_decode, rglru_init
from .ssm import init_ssd_cache, ssd_apply, ssd_decode, ssd_init

__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_caches",
    "parse_kind",
]


def parse_kind(kind: str) -> Tuple[str, Optional[str]]:
    if "+" in kind:
        b, m = kind.split("+")
        return b, m
    return kind, None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, kind: str, cfg: ModelConfig) -> dict:
    block, mlp = parse_kind(kind)
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if block in ("attn", "local"):
        p["attn"] = attn_init(ks[0], cfg)
    elif block == "mla":
        p["attn"] = mla_init(ks[0], cfg)
    elif block == "ssd":
        p["ssd"] = ssd_init(ks[0], cfg)
    elif block == "rglru":
        p["rglru"] = rglru_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {block!r}")
    if mlp == "mlp":
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = mlp_init(ks[1], cfg)
    elif mlp == "moe":
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe"] = moe_init(ks[1], cfg)
    elif mlp is not None:
        raise ValueError(f"unknown mlp kind {mlp!r}")
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 3 + len(cfg.segments))
    vp = cfg.vocab_padded
    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], (vp, cfg.d_model), cfg.dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, vp), cfg.dtype)
    segs = []
    for s, (pattern, n_groups) in enumerate(cfg.segments):
        kseg = jax.random.split(keys[2 + s], n_groups)

        def one_group(k):
            kp = jax.random.split(k, len(pattern))
            return {
                f"pos{j}": _block_init(kp[j], pattern[j], cfg)
                for j in range(len(pattern))
            }

        segs.append(jax.vmap(one_group)(kseg))
    params["segments"] = segs
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill body)
# ---------------------------------------------------------------------------

def _apply_block(p, x, kind: str, cfg: ModelConfig, *, collect_cache: bool):
    """One layer. Returns (x, cache_or_None, aux)."""
    block, mlp = parse_kind(kind)
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    cache = None
    if block in ("attn", "local"):
        window = cfg.window if block == "local" else None
        out, (k, v) = attention_apply(p["attn"], h, cfg, window=window)
        if collect_cache:
            if window and k.shape[1] > window:
                # ring-buffer layout: decode stores position p at slot p % W,
                # so the retained window must be rolled to match
                S = k.shape[1]
                k = jnp.roll(k[:, -window:], S % window, axis=1)
                v = jnp.roll(v[:, -window:], S % window, axis=1)
            cache = {"k": k, "v": v}
    elif block == "mla":
        out, lat = mla_apply(p["attn"], h, cfg)
        if collect_cache:
            cache = {"ckv": lat}
    elif block == "ssd":
        out, st = ssd_apply(p["ssd"], h, cfg)
        if collect_cache:
            cache = st
    elif block == "rglru":
        out, st = rglru_apply(p["rglru"], h, cfg)
        if collect_cache:
            cache = st
    x = x + out
    if mlp == "mlp":
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_type)
    elif mlp == "moe":
        out, aux = moe_apply(p["moe"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        x = x + out
    return x, cache, aux


def apply_remat(fn, policy: str):
    """Wrap a scan body with the configured rematerialisation policy."""
    if policy == "none":
        return fn
    if policy == "nothing":
        return jax.checkpoint(fn, prevent_cse=False)
    if policy == "dots":
        return jax.checkpoint(
            fn,
            prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def _run_segments(params, x, cfg: ModelConfig, *, collect_cache: bool):
    """Scan each segment. Returns (x, caches per segment, total aux)."""
    caches = []
    aux_total = jnp.zeros((), jnp.float32)

    for s, (pattern, n_groups) in enumerate(cfg.segments):
        seg_params = params["segments"][s]

        def group_body(carry, gp, _pattern=pattern):
            h, aux = carry
            cache_out = {}
            for j, kind in enumerate(_pattern):
                h, c, a = _apply_block(
                    gp[f"pos{j}"], h, kind, cfg, collect_cache=collect_cache
                )
                aux = aux + a
                if collect_cache:
                    cache_out[f"pos{j}"] = c
            # pin the scan carry's sharding: the saved-for-backward residuals
            # dominate training memory (sharding/context.py)
            h = constrain(h, "residual")
            return (h, aux), cache_out if collect_cache else None

        body = apply_remat(group_body, cfg.remat_policy)
        (x, aux_total), seg_caches = jax.lax.scan(
            body, (x, aux_total), seg_params
        )
        caches.append(seg_caches)
    return x, caches, aux_total


def forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    prefix_embeds=None,
    collect_cache: bool = False,
):
    """tokens: [B, S] -> logits [B, S(+P), vocab_padded].

    ``prefix_embeds`` ([B, P, D], the [vlm]/[audio] frontend stub output) is
    prepended to the token embeddings; logits cover the full sequence, the
    caller slices the token region for the loss.
    """
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "residual")
    x, caches, aux = _run_segments(params, x, cfg, collect_cache=collect_cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "logits")
    if collect_cache:
        return logits, caches, aux
    return logits, aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract/zero caches mirroring the segment structure."""
    caches = []
    for pattern, n_groups in cfg.segments:
        def one(kind):
            block, _ = parse_kind(kind)
            if block == "attn":
                return init_kv_cache(cfg, batch, max_len)
            if block == "local":
                return init_kv_cache(cfg, batch, max_len, window=cfg.window)
            if block == "mla":
                return init_mla_cache(cfg, batch, max_len)
            if block == "ssd":
                return init_ssd_cache(cfg, batch)
            if block == "rglru":
                return init_rglru_cache(cfg, batch)
            raise ValueError(kind)

        one_group = {f"pos{j}": one(k) for j, k in enumerate(pattern)}
        caches.append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_groups, *x.shape)), one_group
            )
        )
    return caches


def prefill(params, tokens, cfg: ModelConfig, *, prefix_embeds=None):
    """Returns (last-position logits [B, V], caches)."""
    logits, caches, _aux = forward(
        params, tokens, cfg, prefix_embeds=prefix_embeds, collect_cache=True
    )
    return logits[:, -1], caches


def decode_step(params, token, caches, cache_len, cfg: ModelConfig):
    """token: [B, 1] int32; cache_len: [] int32 — valid positions in cache.

    Returns (logits [B, vocab_padded], new caches).
    """
    x = params["embed"][token]  # [B,1,D]
    new_caches = []
    for s, (pattern, n_groups) in enumerate(cfg.segments):
        seg_params = params["segments"][s]
        seg_cache = caches[s]

        def group_body(h, pc, _pattern=pattern):
            gp, gc = pc
            new_gc = {}
            for j, kind in enumerate(_pattern):
                block, mlp = parse_kind(kind)
                p = gp[f"pos{j}"]
                c = gc[f"pos{j}"]
                hn = rms_norm(h, p["norm1"], cfg.norm_eps)
                if block in ("attn", "local"):
                    window = cfg.window if block == "local" else None
                    out, nc = attention_decode(
                        p["attn"], hn, c, cache_len, cfg, window=window
                    )
                elif block == "mla":
                    out, nc = mla_decode(p["attn"], hn, c, cache_len, cfg)
                elif block == "ssd":
                    out, nc = ssd_decode(p["ssd"], hn, c, cfg)
                elif block == "rglru":
                    out, nc = rglru_decode(p["rglru"], hn, c, cfg)
                h = h + out
                if mlp == "mlp":
                    h = h + mlp_apply(
                        p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps), cfg.mlp_type
                    )
                elif mlp == "moe":
                    out, _ = moe_apply(
                        p["moe"], rms_norm(h, p["norm2"], cfg.norm_eps), cfg
                    )
                    h = h + out
                new_gc[f"pos{j}"] = nc
            return h, new_gc

        x, nseg = jax.lax.scan(group_body, x, (seg_params, seg_cache))
        new_caches.append(nseg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, new_caches
