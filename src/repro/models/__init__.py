"""Assigned-architecture model zoo (pure-function JAX, segment-scanned)."""

from .common import ModelConfig, padded_vocab
from .registry import (
    init_params_shape,
    model_caches,
    model_decode,
    model_forward,
    model_init,
    model_prefill,
)

__all__ = [
    "ModelConfig", "padded_vocab", "init_params_shape", "model_caches",
    "model_decode", "model_forward", "model_init", "model_prefill",
]
