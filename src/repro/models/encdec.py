"""Encoder-decoder backbone (whisper-tiny).

Per the [audio] assignment rule the conv/mel frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings [B, S_enc, D].
The backbone is the standard whisper transformer: bidirectional encoder
(learned positions, GeLU MLP), causal decoder with cross-attention.

Decode (serve_step) attends to precomputed encoder K/V (computed once at
prefill) plus a growing self-attention cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .attention import (
    attention_apply,
    attention_decode,
    attn_init,
)
from .common import ModelConfig, dense_init, layer_norm, mlp_apply, mlp_init
from repro.sharding.context import constrain

__all__ = [
    "encdec_init",
    "encode",
    "encdec_forward",
    "encdec_prefill",
    "encdec_decode_step",
    "init_decoder_caches",
]


def _ln_init(cfg):
    return {
        "scale": jnp.ones((cfg.d_model,), jnp.float32),
        "bias": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def encdec_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    vp = cfg.vocab_padded
    enc_layer_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_layer_keys = jax.random.split(ks[1], cfg.n_layers)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _ln_init(cfg),
            "attn": attn_init(k1, cfg),
            "ln2": _ln_init(cfg),
            "mlp": mlp_init(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _ln_init(cfg),
            "self_attn": attn_init(k1, cfg),
            "ln_x": _ln_init(cfg),
            "cross_attn": attn_init(k2, cfg),
            "ln2": _ln_init(cfg),
            "mlp": mlp_init(k3, cfg),
        }

    return {
        "enc_pos": dense_init(ks[2], (cfg.max_pos, cfg.d_model), cfg.dtype, 0.02),
        "dec_pos": dense_init(ks[3], (cfg.max_pos, cfg.d_model), cfg.dtype, 0.02),
        "embed": dense_init(ks[4], (vp, cfg.d_model), cfg.dtype, 0.02),
        "enc_layers": jax.vmap(enc_layer)(enc_layer_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_layer_keys),
        "enc_ln": _ln_init(cfg),
        "dec_ln": _ln_init(cfg),
    }


def _ln(x, p, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, S_enc, D] (frontend stub output) -> encoder states."""
    S = frames.shape[1]
    pos = params["enc_pos"][jnp.arange(S) % cfg.max_pos]
    x = frames.astype(cfg.dtype) + pos[None]

    def body(h, p):
        a, _ = attention_apply(p["attn"], _ln(h, p["ln1"], cfg.norm_eps), cfg, causal=False)
        h = h + a
        h = h + mlp_apply(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps), "gelu")
        return constrain(h, "residual"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_ln"], cfg.norm_eps)


def _decoder(params, x, enc_states, cfg: ModelConfig, *, collect_cache: bool):
    """Teacher-forced decoder. x: [B, S_dec, D] token embeddings (+pos)."""

    def body(carry, p):
        h = carry
        a, kv_self = attention_apply(
            p["self_attn"], _ln(h, p["ln1"], cfg.norm_eps), cfg, causal=True
        )
        h = h + a
        # cross attention: keys/values from encoder states (no rope)
        hq = _ln(h, p["ln_x"], cfg.norm_eps)
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        B, Se = enc_states.shape[0], enc_states.shape[1]
        k = jnp.einsum("bsd,de->bse", enc_states, p["cross_attn"]["wk"]).reshape(
            B, Se, kvh, hd
        )
        v = jnp.einsum("bsd,de->bse", enc_states, p["cross_attn"]["wv"]).reshape(
            B, Se, kvh, hd
        )
        a, kv_cross = attention_apply(
            p["cross_attn"], hq, cfg, causal=False, kv_override=(k, v)
        )
        h = h + a
        h = h + mlp_apply(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps), "gelu")
        h = constrain(h, "residual")
        out = None
        if collect_cache:
            out = {"self": {"k": kv_self[0], "v": kv_self[1]},
                   "cross": {"k": kv_cross[0], "v": kv_cross[1]}}
        return h, out

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return _ln(x, params["dec_ln"], cfg.norm_eps), caches


def encdec_forward(params, frames, dec_tokens, cfg: ModelConfig,
                   *, collect_cache: bool = False):
    """Returns (logits [B, S_dec, vocab_padded], aux=0)."""
    enc = encode(params, frames, cfg)
    S = dec_tokens.shape[1]
    pos = params["dec_pos"][jnp.arange(S) % cfg.max_pos]
    x = params["embed"][dec_tokens] + pos[None]
    x, caches = _decoder(params, x, enc, cfg, collect_cache=collect_cache)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T)
    if collect_cache:
        return logits, caches, jnp.zeros((), jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def encdec_prefill(params, frames, dec_tokens, cfg: ModelConfig):
    logits, caches, _ = encdec_forward(
        params, frames, dec_tokens, cfg, collect_cache=True
    )
    return logits[:, -1], caches


def init_decoder_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    """Abstract decoder caches: growing self cache + fixed cross K/V."""
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    one = {
        "self": {
            "k": jnp.zeros((batch, max_len, kvh, hd), cfg.dtype),
            "v": jnp.zeros((batch, max_len, kvh, hd), cfg.dtype),
        },
        "cross": {
            "k": jnp.zeros((batch, enc_len, kvh, hd), cfg.dtype),
            "v": jnp.zeros((batch, enc_len, kvh, hd), cfg.dtype),
        },
    }
    L = cfg.n_layers
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (L, *x.shape)), one)


def encdec_decode_step(params, token, caches, cache_len, cfg: ModelConfig):
    """One decoder token; cross K/V comes from the caches (precomputed)."""
    B = token.shape[0]
    pos = params["dec_pos"][jnp.minimum(cache_len, cfg.max_pos - 1)]
    x = params["embed"][token] + pos[None, None]

    import math

    def body(h, pc):
        p, c = pc
        hn = _ln(h, p["ln1"], cfg.norm_eps)
        a, nself = attention_decode(p["self_attn"], hn, c["self"], cache_len, cfg)
        h = h + a
        # cross attention against fixed encoder K/V
        hq = _ln(h, p["ln_x"], cfg.norm_eps)
        kvh, hd, nh = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
        q = jnp.einsum("bsd,de->bse", hq, p["cross_attn"]["wq"]).reshape(
            B, 1, nh, hd
        )
        ck, cv = c["cross"]["k"], c["cross"]["v"]
        rep = nh // kvh
        ckx = jnp.repeat(ck, rep, axis=2) if rep > 1 else ck
        cvx = jnp.repeat(cv, rep, axis=2) if rep > 1 else cv
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", q * (1.0 / math.sqrt(hd)), ckx,
            preferred_element_type=jnp.float32,
        )
        pattn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bhqk,bkhd->bqhd", pattn.astype(cvx.dtype), cvx
        ).reshape(B, 1, nh * hd)
        h = h + jnp.einsum("bse,ed->bsd", o, p["cross_attn"]["wo"])
        h = h + mlp_apply(p["mlp"], _ln(h, p["ln2"], cfg.norm_eps), "gelu")
        return h, {"self": nself, "cross": c["cross"]}

    x, ncaches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T)[:, 0]
    return logits, ncaches
