"""Mixture-of-Experts: sort-based capacity dispatch + grouped einsum.

TPU-native formulation (DESIGN.md §2 hardware-adaptation): no [T, E, C]
GShard dispatch tensor (its einsum alone would rival the expert FLOPs at
DeepSeek scale).  Instead:

  1. router top-k -> (expert, weight) per (token, k) slot;
  2. flat sort of T*k assignments by expert id;
  3. scatter into a dense [E, C, D] buffer (capacity C = ceil(T*k/E)*cf,
     overflow dropped — "token dropping", the standard capacity trade);
  4. grouped expert einsum [E,C,D]x[E,D,F] — FLOPs = T*k*cf*D*F*2, i.e.
     model FLOPs times the capacity factor only;
  5. gather back + combine with router weights.

Expert weights shard over the `model` axis: expert dim when divisible
(DeepSeek 160 % 16 == 0 -> true expert parallelism, XLA inserts all_to_all)
else the per-expert FFN dim (Mixtral, 8 experts -> tensor parallel experts).
Shared experts (DeepSeek) are a plain dense MLP added to the MoE output.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    split = cfg.moe_virtual_split
    ev, fv = e * split, f // split
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "experts": {
            # gated (swiglu) expert FFNs, stacked on the (virtual) expert dim
            "w_in": dense_init(ks[1], (ev, d, 2 * fv), cfg.dtype),
            "w_out": dense_init(ks[2], (ev, fv, d), cfg.dtype),
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2 = jax.random.split(ks[3])
        p["shared"] = {
            "w_in": dense_init(k1, (d, 2 * fs), cfg.dtype),
            "w_out": dense_init(k2, (fs, d), cfg.dtype),
        }
    return p


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D]. Returns (out [B,S,D], aux_loss []).

    Dispatch implementation is chosen from the ambient sharding rules:
    when an expert-parallel axis is published (launcher) and the expert
    count is compatible, the shard_map all_to_all path runs (§Perf
    iteration: ~1000x less dispatch traffic than the XLA-resharded dense
    path); otherwise the single-device capacity path below.
    """
    from repro.sharding.context import get_rule

    ep_axis = get_rule("moe_ep_axis")
    mesh = get_rule("mesh")
    if ep_axis is not None and mesh is not None:
        M = mesh.shape[ep_axis]
        ev = cfg.n_experts * cfg.moe_virtual_split
        if ev % M == 0:
            return _moe_ep(params, x, cfg, mesh, ep_axis,
                           get_rule("moe_dp_axes"))
    return _moe_dense(params, x, cfg)


def _route(params, xt, cfg: ModelConfig):
    """Shared routing: top-k over real experts, fanned out to the virtual
    splits.  Returns (idx_v [T, K*split], gate_v, aux)."""
    E, K, split = cfg.n_experts, cfg.top_k, cfg.moe_virtual_split
    T = xt.shape[0]
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    if split > 1:
        idx = (idx[..., None] * split + jnp.arange(split)).reshape(T, K * split)
        gate = jnp.repeat(gate, split, axis=-1)
    return idx, gate, aux


def _moe_dense(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    split = cfg.moe_virtual_split
    E = cfg.n_experts * split
    K = cfg.top_k * split
    T = B * S
    xt = x.reshape(T, D)
    idx, gate, aux = _route(params, xt, cfg)

    # ---- sort-based dispatch -------------------------------------------------
    cap = int((T * K / max(E, 1)) * cfg.capacity_factor) + 1
    flat_e = idx.reshape(-1)  # [T*K]
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e)  # stable; groups by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert = position - segment start
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")  # [E]
    rank = jnp.arange(T * K) - seg_start[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, E * cap)  # OOB -> dropped

    xe = jnp.zeros((E * cap, D), cfg.dtype).at[slot].set(
        xt[st].astype(cfg.dtype), mode="drop"
    )
    xe = xe.reshape(E, cap, D)

    # ---- grouped expert FFN ----------------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", xe, params["experts"]["w_in"])
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["experts"]["w_out"])

    # ---- combine ---------------------------------------------------------------
    ye_flat = ye.reshape(E * cap, D)
    gathered = ye_flat[jnp.minimum(slot, E * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.zeros((T, D), jnp.float32).at[st].add(
        gathered.astype(jnp.float32) * sg[:, None]
    )
    out = out.astype(x.dtype).reshape(B, S, D)

    if "shared" in params:
        out = out + _shared_mlp(params["shared"], x)
    return out, aux


def _shared_mlp(p, x):
    hs = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    g, u = jnp.split(hs, 2, axis=-1)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_out"])


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map all_to_all) — §Perf
# ---------------------------------------------------------------------------
#
# The GraSorw idea at MoE scale (DESIGN.md §2): routed tokens are "walks",
# experts are "blocks"; instead of letting every rank fetch every token
# (XLA's dense resharding = the light random I/O of the paper), tokens are
# *bucketed by destination expert* and exchanged in one sequential
# all_to_all per direction — the bucket I/O of §4.3.
#
# Layout trick: bins are EXPERT-major, [E_v, cap, D]; all_to_all over the
# leading axis hands each rank exactly its experts' tokens in a contiguous
# block, so the local compute is one grouped einsum, no second shuffle.
#
# When the mesh axis is wider than the expert count (mixtral: 8 experts,
# 16-way axis), each expert's FFN is split column-wise into M/E *virtual
# experts* (exact for gated MLPs: silu(x g_h) u_h sums over halves), every
# assignment fans out to all halves, and the combine sums them.

def _moe_ep(params, x, cfg: ModelConfig, mesh, ep_axis: str, dp_axes):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    split = cfg.moe_virtual_split
    E_v = cfg.n_experts * split
    K_v = cfg.top_k * split
    M = mesh.shape[ep_axis]
    epr = E_v // M  # (virtual) experts per rank

    s_ax = ep_axis if S % M == 0 else None
    b_ax = dp_axes if (dp_axes and B % _axes_size(mesh, dp_axes) == 0) else None
    xspec = P(b_ax, s_ax, None)
    wspec = P(ep_axis, None, None)

    def local(xl, w_in_l, w_out_l, router_w):
        """Per-shard: route -> expert-major bins -> a2a -> grouped einsum ->
        a2a back -> combine.  xl: [Bl, Sl, D]; w_*_l: [epr, ...]."""
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, D)
        idx_v, gate_v, aux = _route({"router": router_w}, xt, cfg)

        A = T * K_v
        cap = max(int(A / E_v * cfg.capacity_factor) + 1, 4)
        flat_e = idx_v.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), K_v)
        order = jnp.argsort(flat_e)
        se, st = flat_e[order], flat_t[order]
        seg = jnp.searchsorted(se, jnp.arange(E_v), side="left")
        rank = jnp.arange(A) - seg[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, E_v * cap)  # OOB -> dropped
        bins = jnp.zeros((E_v * cap, D), xl.dtype).at[slot].set(
            xt[st].astype(xl.dtype), mode="drop"
        ).reshape(E_v, cap, D)

        # ---- bucket exchange: one sequential a2a each way (§4.3 analogue)
        recv = jax.lax.all_to_all(
            bins, ep_axis, split_axis=0, concat_axis=0, tiled=True
        )
        # recv rows are source-rank-major: [M, epr, cap, D]
        toks = recv.reshape(M, epr, cap, D).transpose(1, 0, 2, 3)
        toks = toks.reshape(epr, M * cap, D)
        h = jnp.einsum("ecd,edf->ecf", toks, w_in_l)
        g, u = jnp.split(h, 2, axis=-1)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_out_l)
        back = ye.reshape(epr, M, cap, D).transpose(1, 0, 2, 3)
        back = back.reshape(E_v, cap, D)
        ret = jax.lax.all_to_all(
            back, ep_axis, split_axis=0, concat_axis=0, tiled=True
        )  # [E_v, cap, D]: my tokens' outputs, expert-major

        ret_flat = ret.reshape(E_v * cap, D)
        got = ret_flat[jnp.minimum(slot, E_v * cap - 1)]
        got = jnp.where(keep[:, None], got, 0)
        sg = gate_v.reshape(-1)[order]
        out = jnp.zeros((T, D), jnp.float32).at[st].add(
            got.astype(jnp.float32) * sg[:, None]
        )
        for ax in mesh.axis_names:
            aux = jax.lax.pmean(aux, ax)
        return out.astype(xl.dtype).reshape(Bl, Sl, D), aux

    out, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, wspec, wspec, P(None, None)),
        out_specs=(xspec, P()),
        check_rep=False,
    )(x, params["experts"]["w_in"], params["experts"]["w_out"],
      params["router"].astype(jnp.float32))
    if "shared" in params:
        out = out + _shared_mlp(params["shared"], x)
    return out, aux


def _axes_size(mesh, axes):
    import numpy as np

    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))
