"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(c * r_t * log(sigmoid(Lambda)))   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block is: linear in -> causal conv (width 4) -> RG-LRU -> linear out,
gated by a parallel GeLU branch (Griffin's recurrent block).  The linear
recurrence h_t = a_t h_{t-1} + b_t is computed with an associative scan
(log-depth — and shardable along the sequence axis; XLA lowers the
cross-shard combine to a ppermute chain).  Decode carries [B, W] state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init
from .ssm import _causal_conv

__all__ = ["rglru_init", "rglru_apply", "rglru_decode", "init_rglru_cache"]

_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, w), cfg.dtype),  # recurrent branch in
        "w_gate": dense_init(ks[1], (d, w), cfg.dtype),  # gelu gate branch
        "conv": dense_init(ks[2], (cfg.conv_width, w), cfg.dtype, scale=0.5),
        "w_a": dense_init(ks[3], (w, w), cfg.dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[4], (w, w), cfg.dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a ~ uniform(0.9, 0.999) at r = 0.5 (Griffin)
        "lam": jnp.linspace(2.0, 6.0, w, dtype=jnp.float32),
        "w_out": dense_init(ks[5], (w, d), cfg.dtype),
    }


def _gates(params, x):
    """x: [..., w] (post conv). Returns (log_a, b) of the recurrence."""
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", x, params["w_a"]).astype(jnp.float32)
        + params["b_a"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", x, params["w_i"]).astype(jnp.float32)
        + params["b_i"]
    )
    log_a = _C * r * jax.nn.log_sigmoid(params["lam"])  # [..., w], negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    return a, b


def rglru_apply(params, x, cfg: ModelConfig, *, initial_state=None) -> Tuple[jax.Array, dict]:
    """x: [B, S, D].  Returns (out, cache)."""
    B, S, D = x.shape
    xr = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]))
    xc, conv_state = _causal_conv(xr, params["conv"])
    a, b = _gates(params, xc)  # [B,S,w] f32
    if initial_state is not None:
        # fold h0 into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * initial_state)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, params["w_out"])
    return out, {"h": h[:, -1], "conv": conv_state}


def init_rglru_cache(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.dtype),
    }


def rglru_decode(params, x, cache, cfg: ModelConfig):
    """One-token step. x: [B, 1, D]."""
    xr = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]))
    xc, conv_state = _causal_conv(xr, params["conv"], state=cache["conv"])
    a, b = _gates(params, xc[:, 0])
    h = a * cache["h"] + b
    out = (h[:, None].astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, params["w_out"])
    return out, {"h": h, "conv": conv_state}
