"""Mamba-2 (SSD — state-space duality) block.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
``chunk`` positions; within a chunk the output is a (masked) quadratic form
— MXU-friendly matmuls — and across chunks a tiny recurrent state
[heads, head_dim, state] is carried by a `lax.scan`.  This is exactly the
"semiseparable matrix = block-diagonal + low-rank" decomposition of the
paper, and it is what makes the 500k-token cell feasible: O(S * chunk)
compute, O(1) decode state.

Decode is the SSM recurrence: h = exp(dt*A) h + dt * B x ; y = C h.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rms_norm

__all__ = ["ssd_init", "ssd_apply", "ssd_decode", "init_ssd_cache"]


def ssd_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    nh = cfg.ssm_heads
    ns = cfg.ssm_state
    ks = jax.random.split(key, 5)
    # in_proj order: [z (gate) | x | B | C | dt]
    zxbcdt = di + di + ns + ns + nh
    return {
        "w_in": dense_init(ks[0], (d, zxbcdt), cfg.dtype),
        "conv": dense_init(ks[1], (cfg.conv_width, di + 2 * ns), cfg.dtype, scale=0.5),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # per-head decay
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d), cfg.dtype),
    }


def _split_in(params, x, cfg: ModelConfig):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * ns]
    dt = zxbcdt[..., di + di + 2 * ns :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, *, state=None):
    """Depthwise causal conv, width W.  state: [B, W-1, C] tail for decode."""
    W = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(W)
    )
    new_state = xp[:, -(W - 1) :, :] if W > 1 else pad
    return jax.nn.silu(out), new_state


def ssd_apply(params, x, cfg: ModelConfig, *, chunk: int = 256,
              initial_state=None) -> Tuple[jax.Array, dict]:
    """Full-sequence SSD.  x: [B, S, D].  Returns (y, cache)."""
    B, S, D = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_in(params, x, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv"])
    xs = xbc[..., :di].reshape(B, S, nh, hd)
    Bm = xbc[..., di : di + ns]  # [B,S,ns] (single group)
    Cm = xbc[..., di + ns :]

    a = -jnp.exp(params["a_log"])  # [nh] negative decay rates
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,nh]
    dA = dt * a  # [B,S,nh] log-decay per step

    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    CH = chunk
    xs = xs.reshape(B, nc, CH, nh, hd).transpose(1, 0, 3, 2, 4)  # [nc,B,nh,CH,hd]
    Bm = Bm.reshape(B, nc, CH, ns).transpose(1, 0, 2, 3)  # [nc,B,CH,ns]
    Cm = Cm.reshape(B, nc, CH, ns).transpose(1, 0, 2, 3)
    dA = dA.reshape(B, nc, CH, nh).transpose(1, 0, 3, 2)  # [nc,B,nh,CH]
    dtc = dt.reshape(B, nc, CH, nh).transpose(1, 0, 3, 2)

    def chunk_body(h0, inp):
        xs_c, B_c, C_c, dA_c, dt_c = inp
        # cumulative log decay within the chunk
        cum = jnp.cumsum(dA_c, axis=-1)  # [B,nh,CH]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) * dt_j  for j <= i
        # (mask BEFORE exp: the upper triangle has positive exponents that
        # overflow to inf and inf*0 = NaN)
        diff = cum[..., :, None] - cum[..., None, :]  # [B,nh,CH,CH]
        tri = jnp.arange(CH)[:, None] >= jnp.arange(CH)[None, :]
        L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        G = jnp.einsum(
            "bis,bjs->bij", C_c, B_c, preferred_element_type=jnp.float32
        )  # [B,CH,CH]
        M = G[:, None] * L * dt_c[..., None, :]  # [B,nh,CH,CH]
        y_intra = jnp.einsum(
            "bhij,bhjd->bhid", M.astype(xs_c.dtype), xs_c,
            preferred_element_type=jnp.float32,
        )
        # inter-chunk: carried state decayed to each position i, read out by C
        y_inter = jnp.einsum(
            "bis,bhds,bhi->bhid", C_c.astype(jnp.float32), h0, jnp.exp(cum),
            preferred_element_type=jnp.float32,
        )
        y = (y_intra + y_inter).astype(xs_c.dtype)
        # state update: h' = exp(cum_last) h0 + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
        wj = jnp.exp(cum[..., -1:] - cum) * dt_c  # [B,nh,CH]
        h_new = h0 * jnp.exp(cum[..., -1])[..., None, None] + jnp.einsum(
            "bhj,bjs,bhjd->bhds", wj, B_c.astype(jnp.float32),
            xs_c.astype(jnp.float32), preferred_element_type=jnp.float32,
        )
        return h_new, y

    h0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((B, nh, hd, ns), jnp.float32)
    )
    h_final, ys = jax.lax.scan(chunk_body, h0, (xs, Bm, Cm, dA, dtc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, nc * CH, nh, hd)[:, :S]
    y = y + xs.transpose(1, 0, 3, 2, 4).reshape(B, nc * CH, nh, hd)[:, :S] * params[
        "d_skip"
    ][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], 1e-6)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"ssm": h_final, "conv": conv_state}


def init_ssd_cache(cfg: ModelConfig, batch: int):
    return {
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros(
            (batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), cfg.dtype
        ),
    }


def ssd_decode(params, x, cache, cfg: ModelConfig):
    """One-token recurrence. x: [B, 1, D]."""
    B = x.shape[0]
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_in(params, x, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv"], state=cache["conv"])
    xs = xbc[..., :di].reshape(B, nh, hd)
    Bm = xbc[:, 0, di : di + ns]  # [B,ns]
    Cm = xbc[:, 0, di + ns :]
    a = -jnp.exp(params["a_log"])
    dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,nh]
    decay = jnp.exp(dts * a)  # [B,nh]
    h = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bs,bhd->bhds", dts, Bm.astype(jnp.float32), xs.astype(jnp.float32)
    )
    y = jnp.einsum("bs,bhds->bhd", Cm.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], 1e-6)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"ssm": h, "conv": conv_state}
