"""Multi-head Latent Attention (DeepSeek-V2): compressed KV cache.

K/V are generated from a rank-``kv_lora_rank`` latent ``c_kv`` plus a single
shared RoPE key channel; the cache stores only ``[c_kv ; k_rope]``
(kv_lora_rank + qk_rope_dim per token — 576 for the assigned config, a 93 %
cache reduction vs GQA at 128 heads).

Decode uses the *absorbed* formulation (the paper's intended serving mode):
W_UK folds into the query and W_UV into the output projection, so per-token
attention work is O(H * (r + d_rope) * S) against the latent cache directly
— no per-position K/V up-projection.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rope
from .attention import chunked_attention

__all__ = ["mla_init", "mla_apply", "mla_decode", "init_mla_cache"]


def mla_init(key, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        # queries: full-rank projection to per-head (nope ++ rope) parts
        "wq": dense_init(ks[0], (d, h * (dn + dr)), cfg.dtype),
        # latent: d -> r (c_kv) and d -> dr (shared rope key)
        "w_dkv": dense_init(ks[1], (d, r), cfg.dtype),
        "w_krope": dense_init(ks[2], (d, dr), cfg.dtype),
        # up-projections from the latent
        "w_uk": dense_init(ks[3], (r, h * dn), cfg.dtype),
        "w_uv": dense_init(ks[4], (r, h * dv), cfg.dtype),
        "wo": dense_init(ks[5], (h * dv, d), cfg.dtype),
    }


def _project_q(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    sin, cos = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def mla_apply(params, x, cfg: ModelConfig, *, positions=None):
    """Train / prefill.  Returns (out, latent_cache [B,S,r+dr])."""
    B, S, _ = x.shape
    h = cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None]
    q_nope, q_rope = _project_q(params, x, cfg, positions)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])  # latent
    k_rope = jnp.einsum("bsd,de->bse", x, params["w_krope"]).reshape(B, S, 1, dr)
    sin, cos = rope(jnp.arange(S)[None], dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope, sin, cos)

    k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]).reshape(B, S, h, dn)
    v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]).reshape(B, S, h, dv)

    # assemble full per-head keys/queries: [nope ; rope(shared)]
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, h, dr))], -1
    )
    # chunked attention expects matching head dims for q/k; v dim may differ —
    # pad v to qk dim and slice back (keeps one attention primitive)
    dqk = dn + dr
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv))) if dv < dqk else v
    out = chunked_attention(q_full, k_full, v_p, causal=True)[..., :dv]
    out = out.reshape(B, S, h * dv)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"])
    cache = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], -1)  # [B,S,r+dr]
    return out, cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {
        "ckv": jnp.zeros(
            (batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_dim), cfg.dtype
        )
    }


def mla_decode(params, x, cache, cache_len, cfg: ModelConfig):
    """Absorbed decode: score/attend directly in the latent space."""
    B = x.shape[0]
    h = cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    L = cache["ckv"].shape[1]
    pos = cache_len
    q_nope, q_rope = _project_q(params, x, cfg, pos[None, None])  # [B,1,h,*]

    # absorb W_UK into q: q_lat[h, r] = q_nope[h, dn] @ W_UK[r, h*dn]^T
    w_uk = params["w_uk"].reshape(r, h, dn)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)  # [B,1,h,r]

    # append the new token's latent to the cache
    c_new = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    k_rope_new = jnp.einsum("bsd,de->bse", x, params["w_krope"]).reshape(B, 1, 1, dr)
    sin, cos = rope(pos[None, None], dr, cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new, sin, cos)
    entry = jnp.concatenate([c_new, k_rope_new[:, :, 0, :]], -1)
    slot = jnp.minimum(pos, L - 1)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], entry, (0, slot, 0))

    lat, kr = ckv[..., :r], ckv[..., r:]  # [B,L,r], [B,L,dr]
    scale = 1.0 / math.sqrt(dn + dr)
    s = (
        jnp.einsum("bqhr,bkr->bhqk", q_lat, lat, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhe,bke->bhqk", q_rope, kr, preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(L) <= slot
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then absorb W_UV on the way out
    o_lat = jnp.einsum(
        "bhqk,bkr->bqhr", p.astype(lat.dtype), lat,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    w_uv = params["w_uv"].reshape(r, h, dv)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv).reshape(B, 1, h * dv)
    out = jnp.einsum("bse,ed->bsd", o, params["wo"])
    return out, {"ckv": ckv}
