"""Attention: GQA-grouped flash (custom VJP), banded local attention, and
single-token decode against a KV cache.

GQA grouping (§Perf iteration 1): K/V are NEVER expanded to the query head
count — all einsums carry an explicit (kv_head, group) split, so KV HBM
traffic is KVH/H of the naive version (7x less for yi-34b, 6x for mixtral).

The custom VJP is the production-critical part: differentiating the naive
chunk scan stashes O(S^2/chunk) softmax statistics per layer (measured
15 GB/device at yi-34b train_4k); the flash backward recomputes each tile
from (q, k, v, out, lse).

``window`` makes the KV scan *banded*: only the ceil((Cq+W)/Ck)+1 chunks
that can be visible to a q chunk are touched — local attention is O(S*W).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rope

__all__ = [
    "attn_init",
    "attention_apply",
    "attention_decode",
    "chunked_attention",
    "init_kv_cache",
]


def attn_init(key, cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), cfg.dtype),
        "wk": dense_init(ks[1], (d, kvh * hd), cfg.dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), cfg.dtype),
        "wo": dense_init(ks[3], (h * hd, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((kvh * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((kvh * hd,), cfg.dtype)
    return p


def _band_params(banded, nk, q_chunk, kv_chunk, window):
    if not banded:
        return nk
    return min(-(-(q_chunk + window) // kv_chunk) + 1, nk)


def _tile_mask(qi, kj_eff, in_range, causal, window, q_offset, q_chunk,
               kv_chunk, Sk):
    qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
    kpos = kj_eff * kv_chunk + jnp.arange(kv_chunk)
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
        mask &= in_range
    mask &= kpos[None, :] < Sk
    return mask


def chunked_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    q_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KVH, D] with H % KVH == 0."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    qp = nq * q_chunk - Sq
    kp = nk * kv_chunk - Sk
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    out = _flash(causal, window, q_offset, q_chunk, kv_chunk, Sk, G)(q, k, v)
    return out[:, :Sq]


def _flash(causal, window, q_offset, q_chunk, kv_chunk, Sk, G):
    """Factory: custom-VJP GQA flash closed over the static config."""
    banded = window is not None

    def split_chunks(q, k, v):
        B, Sqp, H, D = q.shape
        KVH = k.shape[2]
        nq = Sqp // q_chunk
        nk = k.shape[1] // kv_chunk
        # qc: [nq, B, KVH, G, Cq, D]
        qc = q.reshape(B, nq, q_chunk, KVH, G, D).transpose(1, 0, 3, 4, 2, 5)
        kc = k.reshape(B, nk, kv_chunk, KVH, D).transpose(1, 0, 3, 2, 4)
        vc = v.reshape(B, nk, kv_chunk, KVH, D).transpose(1, 0, 3, 2, 4)
        return qc, kc, vc, nq, nk

    def first_chunk(qi):
        return jnp.maximum((q_offset + qi * q_chunk - window) // kv_chunk, 0)

    def fwd_impl(q, k, v):
        B, Sqp, H, D = q.shape
        scale = 1.0 / math.sqrt(D)
        qc, kc, vc, nq, nk = split_chunks(q, k, v)
        nk_band = _band_params(banded, nk, q_chunk, kv_chunk, window)

        def q_body(_, qi):
            qblk = qc[qi] * scale  # [B,KVH,G,Cq,D]

            def kv_body(carry, kj):
                m, l, acc = carry
                in_range = first_chunk(qi) + kj < nk if banded else True
                kj_eff = (
                    jnp.clip(first_chunk(qi) + kj, 0, nk - 1) if banded else kj
                )
                s = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qblk, kc[kj_eff],
                    preferred_element_type=jnp.float32,
                )
                mask = _tile_mask(qi, kj_eff, in_range, causal, window,
                                  q_offset, q_chunk, kv_chunk, Sk)
                s = jnp.where(mask[None, None, None], s, -1e30)
                m_new = jnp.maximum(m, s.max(-1))
                r = jnp.exp(m - m_new)
                pe = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
                l = l * r + pe.sum(-1)
                acc = acc * r[..., None] + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", pe.astype(vc.dtype), vc[kj_eff],
                    preferred_element_type=jnp.float32,
                )
                return (m_new, l, acc), None

            KVH = qblk.shape[1]
            m0 = jnp.full((qblk.shape[0], KVH, G, q_chunk), -jnp.inf,
                          jnp.float32)
            l0 = jnp.zeros_like(m0)
            a0 = jnp.zeros((*m0.shape, qblk.shape[-1]), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          jnp.arange(nk_band))
            o = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
            lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
            return None, (o, lse)

        _, (outs, lses) = jax.lax.scan(q_body, None, jnp.arange(nq))
        # outs: [nq, B, KVH, G, Cq, D] -> [B, Sq, H, D]
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, D)
        # lses: [nq, B, KVH, G, Cq] -> [B, Sq, KVH, G]
        lse = lses.transpose(1, 0, 4, 2, 3).reshape(B, nq * q_chunk, H // G, G)
        return out, lse

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd_impl(q, k, v)[0]

    def attn_fwd(q, k, v):
        out, lse = fwd_impl(q, k, v)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, dout):
        q, k, v, out, lse = res
        B, Sqp, H, D = q.shape
        KVH = k.shape[2]
        scale = 1.0 / math.sqrt(D)
        qc, kc, vc, nq, nk = split_chunks(q, k, v)
        doc = dout.reshape(B, nq, q_chunk, KVH, G, D).transpose(1, 0, 3, 4, 2, 5)
        lsec = lse.reshape(B, nq, q_chunk, KVH, G).transpose(1, 0, 3, 4, 2)
        Drow = (dout.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
        Dc = Drow.reshape(B, nq, q_chunk, KVH, G).transpose(1, 0, 3, 4, 2)
        nk_band = _band_params(banded, nk, q_chunk, kv_chunk, window)

        def q_body(carry, qi):
            dk_acc, dv_acc = carry  # [nk, B, KVH, Ck, D] f32
            qblk = qc[qi]
            do = doc[qi].astype(jnp.float32)
            lse_i = lsec[qi]
            D_i = Dc[qi]

            def kv_body(carry2, kj):
                dq_i, dk_acc, dv_acc = carry2
                in_range = first_chunk(qi) + kj < nk if banded else True
                kj_eff = (
                    jnp.clip(first_chunk(qi) + kj, 0, nk - 1) if banded else kj
                )
                kblk = kc[kj_eff]
                vblk = vc[kj_eff]
                s = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qblk * scale, kblk,
                    preferred_element_type=jnp.float32,
                )
                mask = _tile_mask(qi, kj_eff, in_range, causal, window,
                                  q_offset, q_chunk, kv_chunk, Sk)
                s = jnp.where(mask[None, None, None], s, -1e30)
                p = jnp.exp(s - lse_i[..., None]) * mask[None, None, None]
                dp = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", do, vblk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - D_i[..., None]) * scale
                dq_i = dq_i + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", ds, kblk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                dk_j = jnp.einsum(  # sum over the query group
                    "bhgqk,bhgqd->bhkd", ds, qblk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                dv_j = jnp.einsum(
                    "bhgqk,bhgqd->bhkd", p, do,
                    preferred_element_type=jnp.float32,
                )
                keep = jnp.where(in_range, 1.0, 0.0) if banded else 1.0
                dk_acc = dk_acc.at[kj_eff].add(keep * dk_j)
                dv_acc = dv_acc.at[kj_eff].add(keep * dv_j)
                return (dq_i, dk_acc, dv_acc), None

            dq0 = jnp.zeros(qblk.shape, jnp.float32)
            (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
                kv_body, (dq0, dk_acc, dv_acc), jnp.arange(nk_band)
            )
            return (dk_acc, dv_acc), dq_i

        dk0 = jnp.zeros((nk, B, KVH, kv_chunk, D), jnp.float32)
        dv0 = jnp.zeros_like(dk0)
        (dk_acc, dv_acc), dqs = jax.lax.scan(q_body, (dk0, dv0), jnp.arange(nq))
        dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, D)
        dk = dk_acc.transpose(1, 0, 3, 2, 4).reshape(B, nk * kv_chunk, KVH, D)
        dv = dv_acc.transpose(1, 0, 3, 2, 4).reshape(B, nk * kv_chunk, KVH, D)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def attention_apply(
    params, x, cfg: ModelConfig, *, window: Optional[int] = None,
    positions=None, causal: bool = True, kv_override=None,
):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, h, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,de->bse", x, params["wk"])
        v = jnp.einsum("bsd,de->bse", x, params["wv"])
        if "bk" in params:
            k = k + params["bk"]
            v = v + params["bv"]
        k = k.reshape(B, -1, kvh, hd)
        v = v.reshape(B, -1, kvh, hd)
    else:
        k, v = kv_override  # cross attention: precomputed from encoder
    if positions is None:
        positions = jnp.arange(S)[None]
    if kv_override is None and not cfg.learned_pos:
        sin, cos = rope(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        kpos = jnp.arange(k.shape[1])[None]
        ksin, kcos = rope(kpos, hd, cfg.rope_theta)
        k = apply_rope(k, ksin, kcos)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(B, S, h * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"]), (k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *, window=None):
    """Cache for one attention layer. Local layers keep only the window."""
    length = min(window, max_len) if window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
    }


def attention_decode(
    params, x, cache, cache_len, cfg: ModelConfig, *, window: Optional[int] = None,
):
    """One-token decode. x: [B, 1, D]; cache k/v: [B, L, KVH, HD];
    cache_len: [] int32 — number of valid cache positions.
    GQA-grouped: the cache is read once, not query-head-many times.
    """
    B = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kvh
    L = cache["k"].shape[1]
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, 1, kvh, g, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = k.reshape(B, 1, kvh, hd)
    v = v.reshape(B, 1, kvh, hd)
    pos = cache_len
    if not cfg.learned_pos:
        sin, cos = rope(pos[None, None], hd, cfg.rope_theta)
        q = apply_rope(
            q.reshape(B, 1, h, hd), sin, cos
        ).reshape(B, 1, kvh, g, hd)
        k = apply_rope(k, sin, cos)
    slot = (pos % L) if window else jnp.minimum(pos, L - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q * (1.0 / math.sqrt(hd)), ck,
        preferred_element_type=jnp.float32,
    )
    idx = jnp.arange(L)
    valid = idx <= slot if window is None else ((idx <= slot) | (pos >= L))
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(B, 1, h * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"]), {"k": ck, "v": cv}
