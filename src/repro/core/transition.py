"""Random-walk transition models (paper §2.1) and walk tasks (§7.1).

A transition model owns the *math* of one step — proposal + acceptance — and
a task owns the walk population and termination rule.  Both are declarative
descriptions consumed by the engines; the actual batched step execution lives
in :mod:`repro.core.engine` / :mod:`repro.kernels`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "TransitionModel",
    "DeepWalk",
    "Node2vec",
    "WalkTask",
    "rwnv_task",
    "prnv_task",
    "deepwalk_task",
]


@dataclasses.dataclass(frozen=True)
class TransitionModel:
    """Base — first-order by default (p(z|v) ∝ a_vz via alias draw)."""

    #: second-order models need N(u); first-order models ignore it
    order: int = 1

    @property
    def name(self) -> str:
        return type(self).__name__.lower()

    def max_bias(self) -> float:
        return 1.0


@dataclasses.dataclass(frozen=True)
class DeepWalk(TransitionModel):
    """First-order: p(z|v) = a_vz / Z_v."""

    order: int = 1


@dataclasses.dataclass(frozen=True)
class Node2vec(TransitionModel):
    """Second-order with return parameter ``p`` and in-out parameter ``q``
    (Eq. 1).  ``p = q = 1`` is the paper's main experimental setting."""

    order: int = 2
    p: float = 1.0
    q: float = 1.0

    def max_bias(self) -> float:
        return max(1.0, 1.0 / self.p, 1.0 / self.q)


@dataclasses.dataclass(frozen=True)
class WalkTask:
    """A walk workload.

    RWNV: ``walks_per_vertex`` walks from *every* vertex, fixed ``length``.
    PRNV: ``total_walks`` walks from ``query_vertex`` with restart
    probability ``1 - decay`` and max length ``length`` (walk-with-restart
    second-order PageRank of Wu et al.).
    """

    model: TransitionModel
    length: int = 80
    walks_per_vertex: int = 10
    query_vertex: Optional[int] = None  # None => start from every vertex
    total_walks: Optional[int] = None  # only for query tasks
    decay: float = 1.0  # termination: continue with prob ``decay`` per step
    seed: int = 0

    def initial_walks(self, num_vertices: int) -> np.ndarray:
        """Source vertex per walk."""
        if self.query_vertex is not None:
            n = self.total_walks if self.total_walks is not None else 4 * num_vertices
            return np.full(n, self.query_vertex, dtype=np.int64)
        return np.repeat(np.arange(num_vertices, dtype=np.int64), self.walks_per_vertex)

    @property
    def uses_restart(self) -> bool:
        return self.decay < 1.0


def rwnv_task(
    p: float = 1.0, q: float = 1.0, *, walks_per_vertex: int = 10, length: int = 80, seed: int = 0
) -> WalkTask:
    """Random Walk generation with the Node2vec model (benchmark 1, §7.1)."""
    return WalkTask(Node2vec(p=p, q=q), length=length, walks_per_vertex=walks_per_vertex, seed=seed)


def prnv_task(
    query_vertex: int,
    num_vertices: int,
    *,
    p: float = 1.0,
    q: float = 1.0,
    decay: float = 0.85,
    length: int = 20,
    samples_per_vertex: int = 4,
    seed: int = 0,
) -> WalkTask:
    """PageRank Query with the Node2vec model (benchmark 2, §7.1)."""
    return WalkTask(
        Node2vec(p=p, q=q),
        length=length,
        query_vertex=query_vertex,
        total_walks=samples_per_vertex * num_vertices,
        decay=decay,
        seed=seed,
    )


def deepwalk_task(*, walks_per_vertex: int = 10, length: int = 80, seed: int = 0) -> WalkTask:
    """First-order DeepWalk task (paper §7.8)."""
    return WalkTask(DeepWalk(), length=length, walks_per_vertex=walks_per_vertex, seed=seed)
