"""Distributed GraSorw — the bi-block engine at pod scale via shard_map.

Mapping (DESIGN.md §2/§5): at pod scale the "disk" is *remote HBM* and a
"block I/O" is a sequential shard transfer over ICI.  Each `model`-axis rank
owns one graph block; walks are sharded over (`data` x `model`).  The
triangular bi-block schedule becomes a **half-ring** schedule:

    for t in 1 .. floor(N_B / 2):
        every rank r holds the pair (block r, block (r + t) mod N_B)
        — one collective_permute per round moves the partner shard —
        and advances every routed walk whose block pair has ring distance t.

Every unordered block pair {a, b} is resident at exactly one rank per sweep
(rank a if (b-a) mod N_B <= N_B/2 else rank b; ties toward min(a, b)) —
precisely the paper's "visit each pair once per sweep, skewed to one side":
Eq. 3's ~50 % block-I/O saving, expressed as ring rounds instead of reads.
Walks are routed to the owning rank with an `all_to_all` (the bucket I/O of
§4.3, now one fused sequential transfer per round) under a static
per-destination capacity; overflow walks wait a round (correctness is
unaffected — a walk only moves when its pair is resident).

Between sweeps, walk state crosses the host boundary through the **shared
sharded walk pool** (:class:`repro.io.ShardedWalkPool`) instead of private
driver arrays: the live frontier is persisted with the same block
association the single-host engines use (skewed ``min(B(u), B(v))``, or
``B(cur)`` for first order) and drained back — scattered to its global
walk-id slot — before the next sweep.  The pool is the same storage tier
the out-of-core engines spill through, so a disk-backed pool moves real
16-byte records and the walk-I/O charges land in the engine's
:class:`~repro.core.stats.IOStats`.  Because the kernel's RNG is
counter-based per (walk id, hop), the roundtrip changes nothing about the
sampled trajectories.

The per-walk step math is `pair_advance_impl` — the same function the
single-host engines jit, drawing through the hand-rolled
:mod:`repro.kernels.rng` threefry (shared with the fused Pallas kernel),
which lowers cleanly inside `shard_map`.  One sampler, one RNG, three
deployment tiers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .engine import pair_advance_impl
from repro.engines.step import VID_PAD, remap_search_iters
from repro.io import ShardedWalkPool
from .buckets import push_by_block_assignment
from .graph import BlockedGraph
from .stats import IOStats
from .transition import Node2vec, WalkTask
from .walk import WalkBatch

__all__ = ["DistributedWalkEngine", "ring_owner_and_round"]


def ring_owner_and_round(a, b, nb: int):
    """Owner rank and ring round for block pair (a, b). Pure / vectorised."""
    d_ab = (b - a) % nb
    d_ba = (a - b) % nb
    tie = d_ab == d_ba  # nb even, distance nb/2
    a_owns = (d_ab < d_ba) | (tie & (a <= b))
    owner = jnp.where(a_owns, a, b)
    rnd = jnp.where(a_owns, d_ab, d_ba)
    rnd = jnp.where(a == b, 0, rnd)
    owner = jnp.where(a == b, a, owner)
    return owner.astype(jnp.int32), rnd.astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BlockShards:
    start: jax.Array  # [NB]  P('model')
    nverts: jax.Array  # [NB]
    indptr: jax.Array  # [NB, MV+1]  P('model', None)
    indices: jax.Array  # [NB, ME]
    alias_j: jax.Array
    alias_q: jax.Array


class DistributedWalkEngine:
    """Walks sharded over (data x model); blocks sharded over 'model'.

    Requires ``bg.num_blocks == mesh.shape[block_axis]`` (one block shard per
    model rank — the natural pod-scale deployment).  Walk state persists
    between sweeps through a shared :class:`repro.io.ShardedWalkPool`
    (``pool``/``pool_shards``/``pool_flush_walks``/``pool_dir``; pass a pool
    instance to share one across engines — the engine then never closes it).
    """

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        mesh: Mesh,
        *,
        data_axes: Tuple[str, ...] = ("data",),
        block_axis: str = "model",
        capacity_factor: float = 2.0,
        k_max: int = 16,
        pool: Union[str, ShardedWalkPool] = "memory",
        pool_shards: Optional[int] = None,
        pool_flush_walks: Optional[int] = 1 << 18,
        pool_dir: Optional[str] = None,
        stats: Optional[IOStats] = None,
    ):
        nb = mesh.shape[block_axis]
        if bg.num_blocks != nb:
            raise ValueError(
                f"num_blocks ({bg.num_blocks}) must equal mesh[{block_axis!r}] ({nb})"
            )
        self.bg = bg
        self.task = task
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.block_axis = block_axis
        self.walk_axes = (*self.data_axes, block_axis)
        self.nb = nb
        self.capacity_factor = capacity_factor
        self.order = task.model.order
        if isinstance(pool, str):
            self.stats = stats if stats is not None else IOStats()
            # one writer shard per model rank by default (shard_of_block
            # stripes, so num_shards == num_blocks is the identity) — the
            # natural deployment where each rank drains its own block pools
            self.pool = ShardedWalkPool(
                pool,
                num_shards=nb if pool_shards is None else pool_shards,
                num_blocks=nb,
                stats=self.stats,
                block_starts=bg.block_starts,
                flush_walks=pool_flush_walks,
                directory=pool_dir,
            )
            self._owns_pool = True
        else:
            self.pool = pool
            self._owns_pool = False
            # a shared pool charges the stats it was built with — report
            # those, not a fresh bundle that never sees its walk I/O
            if stats is None:
                stats = getattr(pool, "stats", None)
            self.stats = stats if stats is not None else IOStats()
        first_order = task.model.order == 1
        trivial_nv = isinstance(task.model, Node2vec) and task.model.p == task.model.q == 1.0
        self.k_max = 1 if first_order or trivial_nv else k_max
        self.n_iters = int(np.ceil(np.log2(max(bg.max_block_edges, 2)))) + 2
        self._blocks = self._stack_blocks()

    # -- block shards ------------------------------------------------------
    def _stack_blocks(self) -> BlockShards:
        bg = self.bg
        nb, mv, me = bg.num_blocks, bg.max_block_verts, bg.max_block_edges
        start = np.zeros(nb, np.int32)
        nverts = np.zeros(nb, np.int32)
        indptr = np.zeros((nb, mv + 1), np.int32)
        indices = np.full((nb, me), -1, np.int32)
        alias_j = np.zeros((nb, me), np.int32)
        alias_q = np.ones((nb, me), np.float32)
        for b in range(nb):
            blk = bg.materialize_block(b)
            start[b], nverts[b] = blk.start, blk.nverts
            indptr[b] = blk.indptr
            indices[b] = blk.indices
            if blk.alias_j is not None:
                alias_j[b], alias_q[b] = blk.alias_j, blk.alias_q
        sh1 = NamedSharding(self.mesh, P(self.block_axis))
        sh2 = NamedSharding(self.mesh, P(self.block_axis, None))
        return BlockShards(
            jax.device_put(start, sh1),
            jax.device_put(nverts, sh1),
            jax.device_put(indptr, sh2),
            jax.device_put(indices, sh2),
            jax.device_put(alias_j, sh2),
            jax.device_put(alias_q, sh2),
        )

    # -- the sharded sweep ----------------------------------------------------
    def _make_sweep(self, capacity: int):
        task, nb = self.task, self.nb
        k_max, n_iters = self.k_max, self.n_iters
        has_alias = self.bg.has_weights
        length = int(task.length)
        baxis = self.block_axis
        block_starts = jnp.asarray(self.bg.block_starts.astype(np.int32))
        OOB = nb * capacity  # out-of-bounds scatter target (mode="drop")

        def blk_of(v):
            return jnp.clip(
                jnp.searchsorted(block_starts, v, side="right") - 1, 0, nb - 1
            ).astype(jnp.int32)

        mv = self.bg.max_block_verts
        v_iters = remap_search_iters(mv)

        def sweep(blocks: BlockShards, prev, cur, hop, alive, key):
            # walk ids are global: linearise the shard rank over the walk
            # axes (matching P(walk_axes) layout) — the counter-based RNG
            # streams are then identical to the single-host engines'
            r = jnp.zeros((), jnp.int32)
            for ax in self.walk_axes:
                r = r * self.mesh.shape[ax] + jax.lax.axis_index(ax)
            own = jax.tree.map(lambda x: x[0], blocks)
            W = prev.shape[0]
            wid0 = r * W + jnp.arange(W, dtype=jnp.int32)

            def make_vids(start, nv):
                k = jnp.arange(mv, dtype=jnp.int32)
                return jnp.where(k < nv, start + k, VID_PAD)

            def round_body(t, state):
                prev, cur, hop, alive, partner, key = state
                # rotate partner shard one ring hop (sequential "block I/O")
                perm = [(i, (i - 1) % nb) for i in range(nb)]
                partner = jax.tree.map(lambda x: jax.lax.ppermute(x, baxis, perm), partner)
                # --- route walks to this round's owner ----------------------
                owner, rnd = ring_owner_and_round(blk_of(prev), blk_of(cur), nb)
                is_init = hop == 0
                owner = jnp.where(is_init, blk_of(cur), owner)
                rnd = jnp.where(is_init, t, rnd)
                want = alive & (rnd == t)
                dest = jnp.where(want, owner, nb)
                one_hot = jax.nn.one_hot(dest, nb + 1, dtype=jnp.int32)
                slot = jnp.cumsum(one_hot, axis=0)[jnp.arange(W), dest] - 1
                routed = want & (slot < capacity)
                flat = jnp.where(routed, dest * capacity + slot, OOB)
                payload = jnp.stack([prev, cur, hop, alive.astype(jnp.int32), wid0], -1)
                send = jnp.full((OOB, 5), -1, jnp.int32)
                send = send.at[flat].set(payload, mode="drop")
                recv = jax.lax.all_to_all(
                    send.reshape(nb, capacity, 5),
                    baxis,
                    split_axis=0,
                    concat_axis=0,
                ).reshape(OOB, 5)
                rmask = recv[:, 0] >= 0
                # --- advance on the resident view pair ----------------------
                own_vids = make_vids(own.start, own.nverts)
                partner_vids = make_vids(partner.start, partner.nverts)
                nprev, ncur, nhop, nalive, _, _ = pair_advance_impl(
                    jnp.concatenate([own_vids, partner_vids]),
                    jnp.stack([own.nverts, partner.nverts]),
                    jnp.array([0, mv], jnp.int32),
                    jnp.concatenate([own.indptr, partner.indptr]),
                    jnp.array([0, mv + 1], jnp.int32),
                    jnp.concatenate([own.indices, partner.indices]),
                    jnp.array([0, own.indices.shape[0]], jnp.int32),
                    jnp.concatenate([own.alias_j, partner.alias_j]),
                    jnp.concatenate([own.alias_q, partner.alias_q]),
                    jnp.where(rmask, recv[:, 4], 0),
                    recv[:, 0],
                    recv[:, 1],
                    recv[:, 2],
                    (recv[:, 3] > 0) & rmask,
                    key,
                    jnp.int32(length),
                    jnp.float32(task.decay),
                    jnp.float32(getattr(task.model, "p", 1.0)),
                    jnp.float32(getattr(task.model, "q", 1.0)),
                    order=task.model.order,
                    k_max=k_max,
                    n_iters=n_iters,
                    v_iters=v_iters,
                    record=False,
                    has_alias=has_alias,
                    max_len=length,
                )
                # --- send results back to the origin shard ------------------
                back = jnp.stack([nprev, ncur, nhop, nalive.astype(jnp.int32)], -1)
                back = jnp.where(rmask[:, None], back, -1)
                back = jax.lax.all_to_all(
                    back.reshape(nb, capacity, 4),
                    baxis,
                    split_axis=0,
                    concat_axis=0,
                ).reshape(OOB, 4)
                # invert the routing: flat slot -> local walk index
                home = jnp.full(OOB, -1, jnp.int32)
                home = home.at[flat].set(jnp.arange(W, dtype=jnp.int32), mode="drop")
                valid = (back[:, 0] >= 0) & (home >= 0)
                # invalid rows scatter out of bounds and are dropped — never
                # write a stale duplicate index (scatter order is undefined)
                tgt = jnp.where(valid, home, W)
                prev = prev.at[tgt].set(back[:, 0], mode="drop")
                cur = cur.at[tgt].set(back[:, 1], mode="drop")
                hop = hop.at[tgt].set(back[:, 2], mode="drop")
                alive = alive.at[tgt].set(back[:, 3] > 0, mode="drop")
                return prev, cur, hop, alive, partner, key

            rounds = max(nb // 2, 1)
            prev, cur, hop, alive, _, _ = jax.lax.fori_loop(
                1, rounds + 1, round_body, (prev, cur, hop, alive, own, key)
            )
            return prev, cur, hop, alive

        return sweep

    # -- walk persistence through the shared pool -----------------------------
    def _persist_frontier(self, src0, prev, cur, hop, alive) -> None:
        """Push the live frontier into the shared pool through the same
        persist helper the single-host engines use (one association rule,
        every tier); walk ids (== global array slots) ride along so the
        drain can scatter each walk back to its slot."""
        live = np.nonzero(alive)[0]
        if live.size == 0:
            return
        batch = WalkBatch(src0[live], prev[live], cur[live], hop[live])
        push_by_block_assignment(
            self.pool, self.bg.block_starts, self.order, batch, live.astype(np.int64)
        )

    def _drain_frontier(self, n_slots: int):
        """Drain every block pool and rebuild the dense sweep arrays by
        scattering each walk to its global walk-id slot, so the
        counter-based RNG streams are untouched by the pool roundtrip.
        All drains are enqueued first (in block order — the program-order
        subsequence per shard, hence deterministic charges) so the shard
        writers drain their disjoint blocks concurrently."""
        prev = np.zeros(n_slots, np.int32)
        cur = np.zeros(n_slots, np.int32)
        hop = np.zeros(n_slots, np.int32)
        alive = np.zeros(n_slots, bool)
        pending = [b for b in range(self.nb) if self.pool.counts[b] > 0]
        for fut in [self.pool.drain_async(b) for b in pending]:
            (batch, wid), _n_walks, _n_spilled = fut.result()
            prev[wid] = batch.prev
            cur[wid] = batch.cur
            hop[wid] = batch.hop
            alive[wid] = True
        return prev, cur, hop, alive

    # -- driver -------------------------------------------------------------
    def run(self, max_sweeps: Optional[int] = None) -> dict:
        task, bg = self.task, self.bg
        src = task.initial_walks(bg.num_vertices).astype(np.int32)
        n = src.shape[0]
        wshards = int(np.prod([self.mesh.shape[a] for a in self.walk_axes]))
        N = int(np.ceil(n / wshards) * wshards)
        pad = N - n
        src0 = np.concatenate([src, np.zeros(pad, np.int32)])
        capacity = max(int(np.ceil((N / wshards) / self.nb * self.capacity_factor)), 8)

        wspec = P(self.walk_axes)
        bspec = BlockShards(
            P(self.block_axis),
            P(self.block_axis),
            P(self.block_axis, None),
            P(self.block_axis, None),
            P(self.block_axis, None),
            P(self.block_axis, None),
        )
        sweep_fn = jax.jit(
            shard_map(
                self._make_sweep(capacity),
                mesh=self.mesh,
                in_specs=(bspec, wspec, wspec, wspec, wspec, P()),
                out_specs=(wspec, wspec, wspec, wspec),
                check_rep=False,
            )
        )
        wsh = NamedSharding(self.mesh, wspec)
        # counter-based RNG: the base key is fixed; draws are keyed per
        # (walk id, hop) inside the kernel, so walks are bit-identical to
        # the single-host engines' for the same task seed
        key = jax.random.PRNGKey(task.seed)

        # the live frontier crosses sweeps through the shared pool; the
        # result arrays accumulate every walk's final state (a retired
        # walk's slot is last written the sweep it died in)
        host_prev = src0.copy()
        host_cur = src0.copy()
        host_hop = np.zeros(N, np.int32)
        host_alive = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
        res_prev = host_prev.copy()
        res_cur = host_cur.copy()
        res_hop = host_hop.copy()
        res_alive = host_alive.copy()

        sweeps = 0
        limit = max_sweeps if max_sweeps is not None else task.length + 8
        try:
            while sweeps < limit and host_alive.any():
                prev = jax.device_put(jnp.asarray(host_prev), wsh)
                cur = jax.device_put(jnp.asarray(host_cur), wsh)
                hop = jax.device_put(jnp.asarray(host_hop), wsh)
                alive = jax.device_put(jnp.asarray(host_alive), wsh)
                prev, cur, hop, alive = sweep_fn(self._blocks, prev, cur, hop, alive, key)
                sweeps += 1
                live_in = host_alive
                host_prev = np.asarray(prev).astype(np.int32)
                host_cur = np.asarray(cur).astype(np.int32)
                host_hop = np.asarray(hop).astype(np.int32)
                host_alive = np.asarray(alive).astype(bool)
                # only walks alive going into the sweep were advanced there
                res_prev[live_in] = host_prev[live_in]
                res_cur[live_in] = host_cur[live_in]
                res_hop[live_in] = host_hop[live_in]
                res_alive[live_in] = host_alive[live_in]
                if not host_alive.any():
                    break
                self._persist_frontier(src0, host_prev, host_cur, host_hop, host_alive)
                host_prev, host_cur, host_hop, host_alive = self._drain_frontier(N)
        finally:
            if self._owns_pool:
                self.pool.close()
        return {
            "prev": res_prev[:n],
            "cur": res_cur[:n],
            "hop": res_hop[:n],
            "alive": res_alive[:n],
            "sweeps": sweeps,
            "stats": self.stats,
        }
