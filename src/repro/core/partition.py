"""Graph partitioners (paper §6.2 / §7.5).

``sequential_partition`` is the paper's default: pack vertices in ID order
into blocks whose CSR slice fits a byte budget.  ``greedy_locality_partition``
is our in-core stand-in for METIS (§7.5): a BFS/label-propagation hybrid that
raises block density (lowers edge-cut) so walks stay inside a block longer —
the property the paper exploits.  Both return either block boundaries (for
ID-contiguous partitions) or a relabelled graph + boundaries.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import BlockedGraph, CSRGraph

__all__ = [
    "sequential_partition",
    "partition_into_n_blocks",
    "greedy_locality_partition",
]


def sequential_partition(graph: CSRGraph, block_size_bytes: int) -> BlockedGraph:
    """Paper default: vertices in ID order; each block's CSR slice (index +
    neighbor cells, 4 bytes each) stays within ``block_size_bytes``."""
    starts = [0]
    v = 0
    V = graph.num_vertices
    indptr = graph.indptr
    while v < V:
        # bytes of block [starts[-1], v]: (nv+1 + ne) * 4
        lo = starts[-1]
        # advance v as far as the budget allows (at least one vertex)
        hi = v + 1
        while hi < V:
            nbytes = 4 * ((hi + 1 - lo + 1) + int(indptr[hi + 1] - indptr[lo]))
            if nbytes > block_size_bytes:
                break
            hi += 1
        starts.append(hi)
        v = hi
    return BlockedGraph(graph, np.asarray(starts, dtype=np.int64))


def partition_into_n_blocks(graph: CSRGraph, num_blocks: int) -> BlockedGraph:
    """Split into exactly ``num_blocks`` blocks of near-equal edge count
    (the paper keeps blocks within 1.03x of each other for METIS runs)."""
    V, E = graph.num_vertices, graph.num_edges
    num_blocks = max(1, min(num_blocks, V))
    target = max(E // num_blocks, 1)
    starts = [0]
    for b in range(1, num_blocks):
        # first vertex whose cumulative edge count crosses b*target
        v = int(np.searchsorted(graph.indptr[1:], b * target, side="left")) + 1
        v = max(v, starts[-1] + 1)
        v = min(v, V - (num_blocks - b))  # leave room for remaining blocks
        starts.append(v)
    starts.append(V)
    return BlockedGraph(graph, np.asarray(starts, dtype=np.int64))


def greedy_locality_partition(
    graph: CSRGraph, num_blocks: int, *, rounds: int = 4, seed: int = 0
) -> Tuple[CSRGraph, BlockedGraph, np.ndarray]:
    """METIS stand-in: BFS grow + label-propagation refinement, then relabel
    vertices so blocks are ID-contiguous (the engine requires contiguity).

    Returns ``(relabelled_graph, blocked, perm)`` where ``perm[old] = new``.
    """
    V = graph.num_vertices
    num_blocks = max(1, min(num_blocks, V))
    cap = int(np.ceil(V / num_blocks))
    rng = np.random.default_rng(seed)
    label = np.full(V, -1, dtype=np.int64)
    sizes = np.zeros(num_blocks, dtype=np.int64)

    # --- seed blocks with BFS growth from high-degree roots -----------------
    order = np.argsort(-graph.degrees)
    b = 0
    for root in order:
        if label[root] != -1 or b >= num_blocks:
            continue
        frontier = [int(root)]
        while frontier and sizes[b] < cap:
            v = frontier.pop()
            if label[v] != -1:
                continue
            label[v] = b
            sizes[b] += 1
            for z in graph.neighbors(v):
                if label[z] == -1:
                    frontier.append(int(z))
        b += 1
    # leftovers round-robin into the emptiest block
    for v in np.where(label == -1)[0]:
        b = int(np.argmin(sizes))
        label[v] = b
        sizes[b] += 1

    # --- label propagation refinement with capacity ------------------------
    src = np.repeat(np.arange(V), graph.degrees.astype(np.int64))
    dst = graph.indices.astype(np.int64)
    for _ in range(rounds):
        for v in rng.permutation(V):
            s, e = graph.indptr[v], graph.indptr[v + 1]
            if s == e:
                continue
            nb = label[graph.indices[s:e]]
            cnt = np.bincount(nb, minlength=num_blocks)
            best = int(np.argmax(cnt))
            cur = int(label[v])
            if best != cur and cnt[best] > cnt[cur] and sizes[best] < int(1.1 * cap) + 1:
                label[v] = best
                sizes[best] += 1
                sizes[cur] -= 1
    del src, dst

    # --- relabel to contiguous ranges --------------------------------------
    perm_order = np.argsort(label, kind="stable")  # old ids grouped by block
    perm = np.empty(V, dtype=np.int64)
    perm[perm_order] = np.arange(V)
    relabelled = graph.relabel(perm)
    counts = np.bincount(label, minlength=num_blocks)
    counts = counts[counts > 0]
    starts = np.zeros(counts.shape[0] + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    return relabelled, BlockedGraph(relabelled, starts), perm
