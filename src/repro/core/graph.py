"""Blocked CSR graph storage — the paper's on-disk layout (Fig. 2/6) in JAX.

The paper stores a graph as CSR partitioned into ``N_B`` blocks; a *Start
Vertex File* records the first vertex of each block, an *Index File* holds
per-vertex neighbor offsets and a *CSR File* the neighbor lists.  Here the
"disk" tier is host memory (numpy) and the "memory" tier is device memory
(jnp arrays); every movement across that boundary is metered by
:mod:`repro.core.stats` so block/vertex I/O counts match the paper's tables.

Blocks are materialised as *stacked, padded* arrays so that a resident block
(or block pair) always has a static shape — the property that lets the walk
advance loop be a single jitted function and lets the Pallas kernels pin a
block pair in VMEM with a fixed BlockSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "CSRGraph",
    "BlockedGraph",
    "ResidentBlock",
    "block_of",
    "activated_bytes",
]


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR graph. ``indices`` rows are sorted (binary-search membership)."""

    indptr: np.ndarray  # [V+1] int64
    indices: np.ndarray  # [E]   int32, sorted within each row
    weights: Optional[np.ndarray] = None  # [E] float32 or None (unweighted)

    def __post_init__(self) -> None:
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int32)
        if self.weights is not None:
            self.weights = np.asarray(self.weights, dtype=np.float32)
            if self.weights.shape != self.indices.shape:
                raise ValueError("weights must align with indices")

    # -- basic accessors ---------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self, v) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1])[v]

    @property
    def degrees(self) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(np.int32)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def neighbor_weights(self, v: int) -> Optional[np.ndarray]:
        if self.weights is None:
            return None
        return self.weights[self.indptr[v] : self.indptr[v + 1]]

    def csr_bytes(self) -> int:
        """Size of the CSR representation (4-byte cells, as in the paper's Fig. 5)."""
        return 4 * (self.indptr.shape[0] + self.indices.shape[0])

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        edges: np.ndarray,
        num_vertices: Optional[int] = None,
        *,
        symmetrize: bool = True,
        weights: Optional[np.ndarray] = None,
        dedup: bool = True,
    ) -> "CSRGraph":
        """Build from an edge list [M, 2]. ``symmetrize`` mirrors the paper
        ("All graphs are processed into undirected")."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if weights is not None:
            weights = np.asarray(weights, dtype=np.float32).reshape(-1)
        if num_vertices is None:
            num_vertices = int(edges.max()) + 1 if edges.size else 0
        if symmetrize and edges.size:
            edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
            if weights is not None:
                weights = np.concatenate([weights, weights], axis=0)
        if edges.size == 0:
            return cls(np.zeros(num_vertices + 1, np.int64), np.zeros(0, np.int32))
        # drop self loops (a second-order walk "return" step is still well
        # defined without them and the paper's datasets are simple graphs)
        keep = edges[:, 0] != edges[:, 1]
        edges = edges[keep]
        if weights is not None:
            weights = weights[keep]
        key = edges[:, 0] * np.int64(num_vertices) + edges[:, 1]
        order = np.argsort(key, kind="stable")
        key = key[order]
        edges = edges[order]
        if weights is not None:
            weights = weights[order]
        if dedup:
            uniq = np.ones(key.shape[0], dtype=bool)
            uniq[1:] = key[1:] != key[:-1]
            edges = edges[uniq]
            if weights is not None:
                weights = weights[uniq]
        counts = np.bincount(edges[:, 0], minlength=num_vertices).astype(np.int64)
        indptr = np.zeros(num_vertices + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr, edges[:, 1].astype(np.int32), weights)

    def relabel(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel vertices: new_id = perm[old_id]. Used by custom partitions."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0])
        src = np.repeat(np.arange(self.num_vertices), self.degrees.astype(np.int64))
        edges = np.stack([perm[src], perm[self.indices]], axis=1)
        return CSRGraph.from_edges(
            edges, self.num_vertices, symmetrize=False,
            weights=self.weights, dedup=False,
        )


def block_of(block_starts: np.ndarray, v) -> np.ndarray:
    """B(v): the block ID owning vertex ``v`` (contiguous vertex ranges)."""
    return np.searchsorted(block_starts, v, side="right") - 1


def activated_bytes(degrees: np.ndarray, vertices: np.ndarray) -> int:
    """Bytes an on-demand load of ``vertices`` moves: one 8-byte index-entry
    pair plus the 4-byte neighbor cells per unique vertex (paper Fig. 5(b)).

    Shared by the in-RAM :class:`BlockedGraph` and the file-backed
    :class:`repro.io.DiskBlockedGraph` so both backends charge identically.
    """
    vertices = np.unique(np.asarray(vertices, dtype=np.int64))
    if vertices.size == 0:
        return 0
    deg = np.asarray(degrees)[vertices].astype(np.int64)
    return int(8 * vertices.size + 4 * deg.sum())


@dataclasses.dataclass
class ResidentBlock:
    """One block resident in "memory" (device arrays, statically padded).

    ``indptr`` is local (offsets into ``indices``); vertex ``v`` maps to local
    row ``v - start``.  ``indices`` holds *global* neighbor IDs, sorted per row.
    """

    block_id: int
    start: int  # first global vertex id
    nverts: int
    nedges: int
    indptr: np.ndarray  # [max_block_verts + 1] int32 (padded with nedges)
    indices: np.ndarray  # [max_block_edges] int32 (padded with -1)
    alias_j: Optional[np.ndarray] = None  # [max_block_edges] int32 alias index
    alias_q: Optional[np.ndarray] = None  # [max_block_edges] float32 alias prob

    def nbytes_full(self) -> int:
        """Bytes a full load moves: index slice + CSR slice (4-byte cells)."""
        return 4 * (self.nverts + 1) + 4 * self.nedges


class BlockedGraph:
    """A CSR graph partitioned into blocks with contiguous vertex ranges.

    Mirrors the paper's sequential partition (§6.2): vertices in ID order are
    packed into blocks such that each block's CSR slice fits ``block_size``
    bytes.  Custom partitions relabel the graph first (see
    :mod:`repro.core.partition`).
    """

    def __init__(self, graph: CSRGraph, block_starts: Sequence[int], *, build_alias: bool = False):
        block_starts = np.asarray(block_starts, dtype=np.int64)
        if block_starts[0] != 0 or block_starts[-1] != graph.num_vertices:
            raise ValueError("block_starts must span [0, V]")
        if np.any(np.diff(block_starts) <= 0):
            raise ValueError("blocks must be non-empty, increasing")
        self.graph = graph
        self.block_starts = block_starts
        self.num_blocks = int(block_starts.shape[0] - 1)
        nverts = np.diff(block_starts)
        estarts = graph.indptr[block_starts]
        nedges = np.diff(estarts)
        self.block_nverts = nverts.astype(np.int64)
        self.block_nedges = nedges.astype(np.int64)
        self.max_block_verts = int(nverts.max())
        self.max_block_edges = max(int(nedges.max()), 1)
        self._build_alias = build_alias
        self._blocks: dict[int, ResidentBlock] = {}

    # -- backend-neutral surface (shared with repro.io.DiskBlockedGraph) ------
    # Engines and the BlockStore only touch this surface plus
    # ``materialize_block``; anything reaching for ``.graph`` directly (the
    # in-memory oracle, partitioners) requires the RAM backend.
    @property
    def num_vertices(self) -> int:
        return self.graph.num_vertices

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    @property
    def degrees(self) -> np.ndarray:
        return self.graph.degrees

    @property
    def has_weights(self) -> bool:
        return self.graph.weights is not None

    def ensure_alias(self) -> None:
        """Ask for alias tables on every materialised block from now on."""
        self._build_alias = True

    # -- paper Table 2 style metadata ---------------------------------------
    def edge_cut(self) -> float:
        """Fraction of edges whose endpoints live in different blocks."""
        src = np.repeat(
            np.arange(self.graph.num_vertices), self.graph.degrees.astype(np.int64)
        )
        bs = block_of(self.block_starts, src)
        bd = block_of(self.block_starts, self.graph.indices)
        if len(bs) == 0:
            return 0.0
        return float(np.mean(bs != bd))

    def block_id_of(self, v) -> np.ndarray:
        return block_of(self.block_starts, v)

    # -- block materialisation ("disk read") --------------------------------
    def materialize_block(self, b: int) -> ResidentBlock:
        """Cut block ``b`` out of the CSR, padded to the global maxima.

        This is a *host* operation; the engine meters the transfer when it
        places the result in "memory".  Results are cached — the cache models
        the OS page cache, but the engine always charges the I/O (the paper
        bypasses the page cache for determinism in its accounting too).
        """
        if b in self._blocks:
            blk = self._blocks[b]
            if self._build_alias and blk.alias_j is None:
                self._attach_alias(blk)
            return blk
        s, e = int(self.block_starts[b]), int(self.block_starts[b + 1])
        es, ee = int(self.graph.indptr[s]), int(self.graph.indptr[e])
        nv, ne = e - s, ee - es
        indptr = np.full(self.max_block_verts + 1, ne, dtype=np.int32)
        indptr[: nv + 1] = (self.graph.indptr[s : e + 1] - es).astype(np.int32)
        indices = np.full(self.max_block_edges, -1, dtype=np.int32)
        indices[:ne] = self.graph.indices[es:ee]
        blk = ResidentBlock(b, s, nv, ne, indptr, indices)
        if self._build_alias:
            self._attach_alias(blk)
        self._blocks[b] = blk
        return blk

    def _attach_alias(self, blk: ResidentBlock) -> None:
        from .sampling import build_alias_rows  # local import: avoid cycle

        w = None
        if self.graph.weights is not None:
            s = int(self.block_starts[blk.block_id])
            es = int(self.graph.indptr[s])
            w = np.zeros(self.max_block_edges, dtype=np.float32)
            w[: blk.nedges] = self.graph.weights[es : es + blk.nedges]
        blk.alias_j, blk.alias_q = build_alias_rows(
            blk.indptr, blk.nverts, self.max_block_edges, w
        )

    def activated_load_bytes(self, vertices: np.ndarray) -> int:
        """Bytes moved by an on-demand load of ``vertices`` (index entry pair
        + each vertex's neighbor segment, as in the paper's Fig. 5(b))."""
        return activated_bytes(self.graph.degrees, vertices)

    def describe(self) -> dict:
        return {
            "num_vertices": self.graph.num_vertices,
            "num_edges": self.graph.num_edges,
            "num_blocks": self.num_blocks,
            "max_block_verts": self.max_block_verts,
            "max_block_edges": self.max_block_edges,
            "csr_bytes": self.graph.csr_bytes(),
            "edge_cut": self.edge_cut(),
        }
