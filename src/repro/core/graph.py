"""Blocked CSR graph storage — the paper's on-disk layout (Fig. 2/6) in JAX.

The paper stores a graph as CSR partitioned into ``N_B`` blocks; a *Start
Vertex File* records the first vertex of each block, an *Index File* holds
per-vertex neighbor offsets and a *CSR File* the neighbor lists.  Here the
"disk" tier is host memory (numpy) and the "memory" tier is device memory
(jnp arrays); every movement across that boundary is metered by
:mod:`repro.core.stats` so block/vertex I/O counts match the paper's tables.

Blocks are materialised as *stacked, padded* arrays so that a resident block
(or block pair) always has a static shape — the property that lets the walk
advance loop be a single jitted function and lets the Pallas kernels pin a
block pair in VMEM with a fixed BlockSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "CSRGraph",
    "BlockedGraph",
    "BlockView",
    "ResidentBlock",
    "block_of",
    "activated_bytes",
]


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR graph. ``indices`` rows are sorted (binary-search membership)."""

    indptr: np.ndarray  # [V+1] int64
    indices: np.ndarray  # [E]   int32, sorted within each row
    weights: Optional[np.ndarray] = None  # [E] float32 or None (unweighted)

    def __post_init__(self) -> None:
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int32)
        if self.weights is not None:
            self.weights = np.asarray(self.weights, dtype=np.float32)
            if self.weights.shape != self.indices.shape:
                raise ValueError("weights must align with indices")

    # -- basic accessors ---------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self, v) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1])[v]

    @property
    def degrees(self) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(np.int32)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def neighbor_weights(self, v: int) -> Optional[np.ndarray]:
        if self.weights is None:
            return None
        return self.weights[self.indptr[v] : self.indptr[v + 1]]

    def csr_bytes(self) -> int:
        """Size of the CSR representation (4-byte cells, as in the paper's Fig. 5)."""
        return 4 * (self.indptr.shape[0] + self.indices.shape[0])

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        edges: np.ndarray,
        num_vertices: Optional[int] = None,
        *,
        symmetrize: bool = True,
        weights: Optional[np.ndarray] = None,
        dedup: bool = True,
    ) -> "CSRGraph":
        """Build from an edge list [M, 2]. ``symmetrize`` mirrors the paper
        ("All graphs are processed into undirected")."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if weights is not None:
            weights = np.asarray(weights, dtype=np.float32).reshape(-1)
        if num_vertices is None:
            num_vertices = int(edges.max()) + 1 if edges.size else 0
        if symmetrize and edges.size:
            edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
            if weights is not None:
                weights = np.concatenate([weights, weights], axis=0)
        if edges.size == 0:
            return cls(np.zeros(num_vertices + 1, np.int64), np.zeros(0, np.int32))
        # drop self loops (a second-order walk "return" step is still well
        # defined without them and the paper's datasets are simple graphs)
        keep = edges[:, 0] != edges[:, 1]
        edges = edges[keep]
        if weights is not None:
            weights = weights[keep]
        key = edges[:, 0] * np.int64(num_vertices) + edges[:, 1]
        order = np.argsort(key, kind="stable")
        key = key[order]
        edges = edges[order]
        if weights is not None:
            weights = weights[order]
        if dedup:
            uniq = np.ones(key.shape[0], dtype=bool)
            uniq[1:] = key[1:] != key[:-1]
            edges = edges[uniq]
            if weights is not None:
                weights = weights[uniq]
        counts = np.bincount(edges[:, 0], minlength=num_vertices).astype(np.int64)
        indptr = np.zeros(num_vertices + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr, edges[:, 1].astype(np.int32), weights)

    def relabel(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel vertices: new_id = perm[old_id]. Used by custom partitions."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0])
        src = np.repeat(np.arange(self.num_vertices), self.degrees.astype(np.int64))
        edges = np.stack([perm[src], perm[self.indices]], axis=1)
        return CSRGraph.from_edges(
            edges,
            self.num_vertices,
            symmetrize=False,
            weights=self.weights,
            dedup=False,
        )


def block_of(block_starts: np.ndarray, v) -> np.ndarray:
    """B(v): the block ID owning vertex ``v`` (contiguous vertex ranges)."""
    return np.searchsorted(block_starts, v, side="right") - 1


def activated_bytes(degrees: np.ndarray, vertices: np.ndarray) -> int:
    """Bytes an on-demand load of ``vertices`` moves: one 8-byte index-entry
    pair plus the 4-byte neighbor cells per unique vertex (paper Fig. 5(b)).

    Shared by the in-RAM :class:`BlockedGraph` and the file-backed
    :class:`repro.io.DiskBlockedGraph` so both backends charge identically.
    """
    vertices = np.unique(np.asarray(vertices, dtype=np.int64))
    if vertices.size == 0:
        return 0
    deg = np.asarray(degrees)[vertices].astype(np.int64)
    return int(8 * vertices.size + 4 * deg.sum())


@dataclasses.dataclass
class ResidentBlock:
    """One block resident in "memory" (device arrays, statically padded).

    ``indptr`` is local (offsets into ``indices``); vertex ``v`` maps to local
    row ``v - start``.  ``indices`` holds *global* neighbor IDs, sorted per row.
    """

    block_id: int
    start: int  # first global vertex id
    nverts: int
    nedges: int
    indptr: np.ndarray  # [max_block_verts + 1] int32 (padded with nedges)
    indices: np.ndarray  # [max_block_edges] int32 (padded with -1)
    alias_j: Optional[np.ndarray] = None  # [max_block_edges] int32 alias index
    alias_q: Optional[np.ndarray] = None  # [max_block_edges] float32 alias prob

    def nbytes_full(self) -> int:
        """Bytes a full load moves: index slice + CSR slice (4-byte cells)."""
        return 4 * (self.nverts + 1) + 4 * self.nedges


@dataclasses.dataclass
class BlockView:
    """A (possibly partial) *view* of one block — the currency between the
    storage layer and execution.

    A view is a compacted local CSR over the vertices it holds: ``vids`` is
    the sorted array of global vertex ids with a row in the view (the remap
    table — the kernel resolves a global vertex to its compact row by binary
    search over ``vids``), ``indptr``/``indices`` the compact CSR.  Two kinds:

    * ``kind == "full"`` — every vertex of the block; ``vids`` is the
      contiguous range ``[start, start + nverts)``.  Built from a
      :class:`ResidentBlock` (a full block load).
    * ``kind == "activated"`` — only the bucket's activated vertices (the
      ``prev``/``cur`` of some walk), so device bytes are
      ``O(activated vertices)`` instead of ``O(block)``.  Built by
      ``partial_view`` on either graph backend, and *extended* mid-advance
      when a walk reaches a vertex that was not pre-activated.

    Rows a view holds are bit-identical to the full block's rows (same
    neighbor order, same row-local alias tables), which is what makes
    execution on an activated view produce the same walks as a full load.
    """

    block_id: int
    kind: str  # "full" | "activated"
    vids: np.ndarray  # [K] int32, sorted global vertex ids (the remap table)
    indptr: np.ndarray  # [K+1] int32, compact local offsets
    indices: np.ndarray  # [nnz] int32, global neighbor ids (sorted per row)
    alias_j: Optional[np.ndarray] = None  # [nnz] int32, row-local alias slots
    alias_q: Optional[np.ndarray] = None  # [nnz] float32

    @property
    def nverts(self) -> int:
        return int(self.vids.shape[0])

    @property
    def nedges(self) -> int:
        return int(self.indices.shape[0])

    def nbytes(self) -> int:
        """Data bytes of the compact view (remap + index + CSR, 4-byte cells,
        plus the alias pair when present)."""
        n = 4 * self.nverts + 4 * (self.nverts + 1) + 4 * self.nedges
        if self.alias_j is not None:
            n += 8 * self.nedges
        return n

    def has_vertices(self, vertices: np.ndarray) -> np.ndarray:
        """Boolean mask: which of ``vertices`` have a row in this view."""
        vertices = np.asarray(vertices)
        pos = np.searchsorted(self.vids, vertices)
        pos_c = np.minimum(pos, max(self.nverts - 1, 0))
        if self.nverts == 0:
            return np.zeros(vertices.shape, bool)
        return self.vids[pos_c] == vertices

    @classmethod
    def from_resident(cls, blk: ResidentBlock) -> "BlockView":
        """Full view of a materialised block (zero-copy slices)."""
        nv, ne = blk.nverts, blk.nedges
        return cls(
            block_id=blk.block_id,
            kind="full",
            vids=(blk.start + np.arange(nv)).astype(np.int32),
            indptr=blk.indptr[: nv + 1],
            indices=blk.indices[:ne],
            alias_j=None if blk.alias_j is None else blk.alias_j[:ne],
            alias_q=None if blk.alias_q is None else blk.alias_q[:ne],
        )

    @classmethod
    def from_rows(
        cls,
        block_id: int,
        vids: np.ndarray,
        segs: Sequence[np.ndarray],
        alias_segs: Optional[Sequence] = None,
        *,
        kind: str = "activated",
    ) -> "BlockView":
        """Assemble a view from per-vertex row segments (``vids`` sorted,
        ``segs[k]`` the neighbor list of ``vids[k]``)."""
        k = len(segs)
        indptr = np.zeros(k + 1, dtype=np.int32)
        if k:
            sizes = np.array([s.size for s in segs], dtype=np.int64)
            indptr[1:] = np.cumsum(sizes).astype(np.int32)
        indices = np.concatenate(segs).astype(np.int32) if k else np.zeros(0, np.int32)
        alias_j = alias_q = None
        if alias_segs is not None:
            alias_j = (
                np.concatenate([a for a, _ in alias_segs]).astype(np.int32)
                if k
                else np.zeros(0, np.int32)
            )
            alias_q = (
                np.concatenate([q for _, q in alias_segs]).astype(np.float32)
                if k
                else np.zeros(0, np.float32)
            )
        return cls(
            block_id=block_id,
            kind=kind,
            vids=np.asarray(vids, dtype=np.int32),
            indptr=indptr,
            indices=indices,
            alias_j=alias_j,
            alias_q=alias_q,
        )

    def row(self, k: int) -> np.ndarray:
        return self.indices[self.indptr[k] : self.indptr[k + 1]]

    def _alias_row(self, k: int):
        s, e = self.indptr[k], self.indptr[k + 1]
        return (self.alias_j[s:e], self.alias_q[s:e])

    def extended(self, other: "BlockView") -> "BlockView":
        """A new activated view holding this view's rows plus ``other``'s
        (the mid-advance *extension gather*: ``other`` carries the rows of
        vertices reached during execution that were not pre-activated).
        Vertex sets must be disjoint."""
        if other.block_id != self.block_id:
            raise ValueError("cannot extend a view with rows of another block")
        merged = np.concatenate([self.vids, other.vids])
        order = np.argsort(merged, kind="stable")
        views = [self] * self.nverts + [other] * other.nverts
        local = list(range(self.nverts)) + list(range(other.nverts))
        segs = [views[i].row(local[i]) for i in order]
        alias_segs = None
        if self.alias_j is not None:
            alias_segs = [views[i]._alias_row(local[i]) for i in order]
        return BlockView.from_rows(self.block_id, merged[order], segs, alias_segs, kind="activated")


class BlockedGraph:
    """A CSR graph partitioned into blocks with contiguous vertex ranges.

    Mirrors the paper's sequential partition (§6.2): vertices in ID order are
    packed into blocks such that each block's CSR slice fits ``block_size``
    bytes.  Custom partitions relabel the graph first (see
    :mod:`repro.core.partition`).
    """

    def __init__(self, graph: CSRGraph, block_starts: Sequence[int], *, build_alias: bool = False):
        block_starts = np.asarray(block_starts, dtype=np.int64)
        if block_starts[0] != 0 or block_starts[-1] != graph.num_vertices:
            raise ValueError("block_starts must span [0, V]")
        if np.any(np.diff(block_starts) <= 0):
            raise ValueError("blocks must be non-empty, increasing")
        self.graph = graph
        self.block_starts = block_starts
        self.num_blocks = int(block_starts.shape[0] - 1)
        nverts = np.diff(block_starts)
        estarts = graph.indptr[block_starts]
        nedges = np.diff(estarts)
        self.block_nverts = nverts.astype(np.int64)
        self.block_nedges = nedges.astype(np.int64)
        self.max_block_verts = int(nverts.max())
        self.max_block_edges = max(int(nedges.max()), 1)
        self._build_alias = build_alias
        self._blocks: dict[int, ResidentBlock] = {}
        # Waste budget (bytes) of the gap-aware on-demand read planner
        # (repro.io.ioplan).  The RAM backend performs no real reads, but the
        # BlockStore meters the planner's modelled gauges off this knob so
        # accounting is backend-invariant.  0 = planner off (per-vertex
        # reference reads).
        self.io_coalesce_gap = 0

    # -- backend-neutral surface (shared with repro.io.DiskBlockedGraph) ------
    # Engines and the BlockStore only touch this surface plus
    # ``materialize_block``; anything reaching for ``.graph`` directly (the
    # in-memory oracle, partitioners) requires the RAM backend.
    @property
    def num_vertices(self) -> int:
        return self.graph.num_vertices

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    @property
    def degrees(self) -> np.ndarray:
        return self.graph.degrees

    @property
    def has_weights(self) -> bool:
        return self.graph.weights is not None

    def ensure_alias(self) -> None:
        """Ask for alias tables on every materialised block from now on."""
        self._build_alias = True

    def row_extents(self, vertices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Global CSR edge range ``[rs, re)`` per vertex of a sorted unique
        ``vertices`` array — resident metadata only, no I/O.  The read
        planner's input on either backend."""
        vs = np.asarray(vertices, dtype=np.int64)
        return self.graph.indptr[vs], self.graph.indptr[vs + 1]

    # -- paper Table 2 style metadata ---------------------------------------
    def edge_cut(self) -> float:
        """Fraction of edges whose endpoints live in different blocks."""
        src = np.repeat(np.arange(self.graph.num_vertices), self.graph.degrees.astype(np.int64))
        bs = block_of(self.block_starts, src)
        bd = block_of(self.block_starts, self.graph.indices)
        if len(bs) == 0:
            return 0.0
        return float(np.mean(bs != bd))

    def block_id_of(self, v) -> np.ndarray:
        return block_of(self.block_starts, v)

    # -- block materialisation ("disk read") --------------------------------
    def materialize_block(self, b: int) -> ResidentBlock:
        """Cut block ``b`` out of the CSR, padded to the global maxima.

        This is a *host* operation; the engine meters the transfer when it
        places the result in "memory".  Results are cached — the cache models
        the OS page cache, but the engine always charges the I/O (the paper
        bypasses the page cache for determinism in its accounting too).
        """
        if b in self._blocks:
            blk = self._blocks[b]
            if self._build_alias and blk.alias_j is None:
                self._attach_alias(blk)
            return blk
        s, e = int(self.block_starts[b]), int(self.block_starts[b + 1])
        es, ee = int(self.graph.indptr[s]), int(self.graph.indptr[e])
        nv, ne = e - s, ee - es
        indptr = np.full(self.max_block_verts + 1, ne, dtype=np.int32)
        indptr[: nv + 1] = (self.graph.indptr[s : e + 1] - es).astype(np.int32)
        indices = np.full(self.max_block_edges, -1, dtype=np.int32)
        indices[:ne] = self.graph.indices[es:ee]
        blk = ResidentBlock(b, s, nv, ne, indptr, indices)
        if self._build_alias:
            self._attach_alias(blk)
        self._blocks[b] = blk
        return blk

    def _attach_alias(self, blk: ResidentBlock) -> None:
        from .sampling import build_alias_rows  # local import: avoid cycle

        w = None
        if self.graph.weights is not None:
            s = int(self.block_starts[blk.block_id])
            es = int(self.graph.indptr[s])
            w = np.zeros(self.max_block_edges, dtype=np.float32)
            w[: blk.nedges] = self.graph.weights[es : es + blk.nedges]
        blk.alias_j, blk.alias_q = build_alias_rows(blk.indptr, blk.nverts, self.max_block_edges, w)

    def activated_load_bytes(self, vertices: np.ndarray) -> int:
        """Bytes moved by an on-demand load of ``vertices`` (index entry pair
        + each vertex's neighbor segment, as in the paper's Fig. 5(b))."""
        return activated_bytes(self.graph.degrees, vertices)

    def partial_view(self, b: int, vertices: np.ndarray) -> BlockView:
        """An *activated* :class:`BlockView` of block ``b``: a compacted
        local CSR over only the (unique) requested vertices plus the remap
        table.  Rows are cut straight from the host CSR; row-local alias
        tables are built with the same builder a full block uses, so a row
        is bit-identical to its full-load twin.  Mirrors
        ``DiskBlockedGraph.partial_view`` (which performs real partial
        reads); the *engine* charges the transfer either way.
        """
        s, e = int(self.block_starts[b]), int(self.block_starts[b + 1])
        vids = np.unique(np.asarray(vertices, dtype=np.int64))
        if vids.size and (vids[0] < s or vids[-1] >= e):
            raise IndexError(f"vertices outside block {b} range [{s}, {e})")
        return self._rows_view(b, vids)

    def gather_view(self, vertices: np.ndarray) -> BlockView:
        """A cross-block activated view (``block_id == -1``): the rows of
        arbitrary vertices, compacted.  What a baseline's per-walk vertex
        fetches pin in "memory" (e.g. SOGW's out-of-block previous-vertex
        adjacencies), so execution uses exactly the rows the engine charged
        for."""
        return self._rows_view(-1, np.unique(np.asarray(vertices, dtype=np.int64)))

    def _rows_view(self, block_id: int, vids: np.ndarray) -> BlockView:
        g = self.graph
        segs = [g.indices[g.indptr[v] : g.indptr[v + 1]] for v in vids]
        alias_segs = None
        if self._build_alias:
            from .sampling import build_alias  # local import: avoid cycle

            alias_segs = []
            for k, v in enumerate(vids):
                w = (
                    g.weights[g.indptr[v] : g.indptr[v + 1]]
                    if g.weights is not None
                    else np.ones(segs[k].size)
                )
                if segs[k].size:
                    alias_segs.append(build_alias(w))
                else:
                    alias_segs.append((np.zeros(0, np.int32), np.zeros(0, np.float32)))
        return BlockView.from_rows(block_id, vids, segs, alias_segs)

    def describe(self) -> dict:
        return {
            "num_vertices": self.graph.num_vertices,
            "num_edges": self.graph.num_edges,
            "num_blocks": self.num_blocks,
            "max_block_verts": self.max_block_verts,
            "max_block_edges": self.max_block_edges,
            "csr_bytes": self.graph.csr_bytes(),
            "edge_cut": self.edge_cut(),
        }
