"""I/O accounting — the quantities in the paper's Tables 3/4/7.

Every transfer across the slow/fast boundary is metered here.  Costs are both
*counted* (number of block I/Os, vertex I/Os, bytes) and *modelled* in seconds
against a device preset, so benchmark results are deterministic on any host.
The presets expose the paper's regime (SSD: cheap sequential, ruinous random)
and the TPU regime the system targets (HBM / ICI), which share that shape.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict

__all__ = ["DevicePreset", "SSD", "HBM_V5E", "ICI_V5E", "IOStats"]


@dataclasses.dataclass(frozen=True)
class DevicePreset:
    """Bandwidth/latency model of the slow tier."""

    name: str
    seq_bandwidth: float  # bytes/s for sequential block transfers
    rand_latency: float  # seconds per random I/O (seek / gather setup)
    rand_bandwidth: float  # bytes/s once a random transfer streams

    def seq_cost(self, nbytes: int) -> float:
        return self.rand_latency + nbytes / self.seq_bandwidth

    def rand_cost(self, n_ios: int, nbytes: int) -> float:
        return n_ios * self.rand_latency + nbytes / self.rand_bandwidth


# An NVMe SSD like the paper's testbed: ~2 GB/s sequential, ~80 us random.
SSD = DevicePreset("ssd", 2.0e9, 8.0e-5, 4.0e8)
# TPU v5e HBM (the slow tier vs VMEM): 819 GB/s, ~1 us "gather setup".
HBM_V5E = DevicePreset("hbm_v5e", 8.19e11, 1.0e-6, 8.19e10)
# TPU v5e ICI link (the slow tier vs local HBM at pod scale): 50 GB/s/link.
ICI_V5E = DevicePreset("ici_v5e", 5.0e10, 1.0e-6, 5.0e9)


class IOStats:
    """Counter bundle; mirrors the decomposition in the paper's Fig. 1(a)."""

    def __init__(self, preset: DevicePreset = SSD):
        self.preset = preset
        # walk_io is the one counter path hit from multiple writer threads
        # (one per pool shard); everything else stays single-producer
        self._walk_lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        self.block_ios = 0
        self.block_bytes = 0
        self.vertex_ios = 0
        self.vertex_bytes = 0
        self.walk_ios = 0
        self.walk_bytes = 0
        self.walk_bytes_written = 0
        self.walk_bytes_read = 0
        self.ondemand_ios = 0
        self.ondemand_bytes = 0
        self.ondemand_syscalls = 0
        self.coalesced_ranges = 0
        self.coalesce_waste_bytes = 0
        self.hot_pinned_blocks = 0
        self.pinned_block_hits = 0
        self.pinned_bytes_saved = 0
        self.peak_resident_bytes = 0
        self.overlapped_load_bytes = 0
        self.pipeline_stall_slots = 0
        self.writer_queue_peak = 0
        self.shard_spill_bytes: dict = {}
        self.shard_imbalance = 0.0
        self.time_slots = 0
        self.supersteps = 0
        self.steps_sampled = 0
        self.bucket_executions = 0
        self.sim_block_io_time = 0.0
        self.sim_vertex_io_time = 0.0
        self.sim_ondemand_io_time = 0.0
        self.exec_time = 0.0  # wall time inside walk updating
        self.wall_start = time.perf_counter()
        self.per_block_loads = defaultdict(int)

    # -- metering ------------------------------------------------------------
    def block_load(self, block_id: int, nbytes: int, *, sequential: bool) -> None:
        self.block_ios += 1
        self.block_bytes += nbytes
        self.per_block_loads[block_id] += 1
        if sequential:
            self.sim_block_io_time += self.preset.seq_cost(nbytes)
        else:
            self.sim_block_io_time += self.preset.rand_cost(1, nbytes)

    def vertex_load(self, n_vertices: int, nbytes: int) -> None:
        self.vertex_ios += n_vertices
        self.vertex_bytes += nbytes
        self.sim_vertex_io_time += self.preset.rand_cost(n_vertices, nbytes)

    def ondemand_load(
        self,
        n_vertices: int,
        nbytes: int,
        *,
        seeks: int | None = None,
        waste_bytes: int = 0,
    ) -> None:
        """Charge an on-demand gather: ``n_vertices`` vertex I/Os moving
        ``nbytes`` *useful* bytes.  With the gap-aware read planner on, the
        caller passes the observed ``seeks`` (coalesced ranges actually
        issued) and read-through ``waste_bytes``, and the modelled time pays
        one seek per range plus streaming over useful+wasted bytes — the
        loader's per-seek cost term.  ``seeks=None`` (planner off) keeps the
        bit-exact reference charge of one random I/O per vertex.  The
        ``ondemand_ios``/``ondemand_bytes`` counters always count vertices
        and useful bytes, so charged useful bytes never depend on the gap."""
        self.ondemand_ios += n_vertices
        self.ondemand_bytes += nbytes
        if seeks is None:
            self.sim_ondemand_io_time += self.preset.rand_cost(n_vertices, nbytes)
        else:
            p = self.preset
            self.sim_ondemand_io_time += seeks * p.rand_latency + (
                nbytes + waste_bytes
            ) / p.rand_bandwidth

    def note_ondemand_plan(self, syscalls: int, ranges: int, waste_bytes: int) -> None:
        """Gauges: what the on-demand read planner actually did.
        ``ondemand_syscalls`` counts every ``pread`` the on-demand path
        issues (4 tiny ones per vertex on the reference path, one large one
        per coalesced range with the planner on); ``coalesced_ranges``
        counts only planner-issued ranges; ``coalesce_waste_bytes`` is the
        read-through hole bytes those ranges carried beyond the useful
        extents.  Metered from the pure plan model on either graph backend,
        so the values are deterministic and backend-invariant."""
        self.ondemand_syscalls += int(syscalls)
        self.coalesced_ranges += int(ranges)
        self.coalesce_waste_bytes += int(waste_bytes)

    def note_hot_set(self, n_blocks: int) -> None:
        """Gauge: blocks currently pinned resident by the
        :class:`~repro.io.BlockStore` hot-set policy (serving layer).  Set
        at every (program-ordered) pinning decision, so the value reflects
        the final policy state, never thread timing."""
        self.hot_pinned_blocks = int(n_blocks)

    def note_pinned_hit(self, nbytes: int) -> None:
        """Counter: a charged ``get`` served from the pinned hot set.  The
        ``block_load`` charge is *skipped* — the block never re-crossed the
        slow/fast boundary — and the avoided bytes accumulate in
        ``pinned_bytes_saved``.  Deterministic: pinned membership and the
        access sequence are both program-order pure."""
        self.pinned_block_hits += 1
        self.pinned_bytes_saved += int(nbytes)

    def note_resident(self, nbytes: int) -> None:
        """Gauge: bytes of graph data resident in "memory" (the device view
        pair) right now.  ``peak_resident_bytes`` is the high-water mark —
        the footprint on-demand *execution* shrinks versus full loads."""
        self.peak_resident_bytes = max(self.peak_resident_bytes, int(nbytes))

    def note_overlapped(self, nbytes: int) -> None:
        """Counter: bytes whose load was *initiated off the critical path*
        by a background worker (block/partial-view prefetch thread,
        walk-pool writer preload) and later consumed by the engine.  The
        serial reference mode still reports its prefetch-thread hits here —
        it was never prefetch-free; the async pipeline's *additional*
        overlap is the delta against it (the ``pipeline_overlap`` bench
        asserts it is positive).  Never part of the deterministic I/O
        charges."""
        self.overlapped_load_bytes += int(nbytes)

    def note_stall_slot(self) -> None:
        """Counter: a time slot whose walk-pool load ran synchronously on
        the critical path (the pipeline had no preload in flight — serial
        mode, the first slot of a run, or a mispredicted next slot)."""
        self.pipeline_stall_slots += 1

    def note_writer_queue(self, depth: int) -> None:
        """Gauge: walk-pool writer queue depth; keeps the high-water mark."""
        self.writer_queue_peak = max(self.writer_queue_peak, int(depth))

    def note_shard_imbalance(self, value: float) -> None:
        """Gauge: max-over-mean ratio of walks pushed per pool shard.

        Updated at every (program-ordered) push, so the value — like the
        per-shard breakdown in ``shard_spill_bytes`` — is deterministic: it
        reflects how the keyspace hash distributed the final push totals,
        never thread timing."""
        self.shard_imbalance = float(value)

    def walk_io(
        self,
        n_walks: int,
        *,
        bytes_per_walk: int = 16,
        kind: str = "write",
        shard: int | None = None,
    ) -> None:
        """Walk pool flush/load: 128-bit encoded walks (paper §6.1).

        ``kind`` distinguishes spills (``"write"``) from pool loads
        (``"read"``) so ``walk_bytes_written`` can be checked against the
        bytes a :class:`repro.io.DiskWalkPool` actually put on disk.
        ``shard`` attributes a spill to one pool shard's writer
        (``shard_spill_bytes`` breakdown); shard writers run on their own
        threads, so the whole update is taken under one lock.
        """
        nbytes = n_walks * bytes_per_walk
        with self._walk_lock:
            self.walk_ios += 1
            self.walk_bytes += nbytes
            if kind == "write":
                self.walk_bytes_written += nbytes
                if shard is not None:
                    self.shard_spill_bytes[shard] = self.shard_spill_bytes.get(shard, 0) + nbytes
            else:
                self.walk_bytes_read += nbytes

    # -- summaries -------------------------------------------------------------
    @property
    def sim_walk_io_time(self) -> float:
        """Modelled walk-I/O seconds: ``walk_ios`` sequential transfers of
        ``walk_bytes`` total.  Derived from the order-independent integer
        counters instead of accumulated per call, so concurrent shard
        writers cannot perturb the float-summation order — the value is
        bit-deterministic at any shard count."""
        p = self.preset
        return self.walk_ios * p.rand_latency + self.walk_bytes / p.seq_bandwidth

    @property
    def sim_io_time(self) -> float:
        return (
            self.sim_block_io_time
            + self.sim_vertex_io_time
            + self.sim_ondemand_io_time
            + self.sim_walk_io_time
        )

    @property
    def sim_wall_time(self) -> float:
        return self.sim_io_time + self.exec_time

    def as_dict(self) -> dict:
        return {
            "block_ios": self.block_ios,
            "block_bytes": self.block_bytes,
            "vertex_ios": self.vertex_ios,
            "vertex_bytes": self.vertex_bytes,
            "ondemand_ios": self.ondemand_ios,
            "ondemand_bytes": self.ondemand_bytes,
            "ondemand_syscalls": self.ondemand_syscalls,
            "coalesced_ranges": self.coalesced_ranges,
            "coalesce_waste_bytes": self.coalesce_waste_bytes,
            "hot_pinned_blocks": self.hot_pinned_blocks,
            "pinned_block_hits": self.pinned_block_hits,
            "pinned_bytes_saved": self.pinned_bytes_saved,
            "walk_ios": self.walk_ios,
            "walk_bytes": self.walk_bytes,
            "walk_bytes_written": self.walk_bytes_written,
            "walk_bytes_read": self.walk_bytes_read,
            "peak_resident_bytes": self.peak_resident_bytes,
            "overlapped_load_bytes": self.overlapped_load_bytes,
            "pipeline_stall_slots": self.pipeline_stall_slots,
            "writer_queue_peak": self.writer_queue_peak,
            "shard_spill_bytes": dict(sorted(self.shard_spill_bytes.items())),
            "shard_imbalance": self.shard_imbalance,
            "time_slots": self.time_slots,
            "supersteps": self.supersteps,
            "steps_sampled": self.steps_sampled,
            "bucket_executions": self.bucket_executions,
            "sim_block_io_time": self.sim_block_io_time,
            "sim_vertex_io_time": self.sim_vertex_io_time,
            "sim_ondemand_io_time": self.sim_ondemand_io_time,
            "sim_walk_io_time": self.sim_walk_io_time,
            "sim_io_time": self.sim_io_time,
            "exec_time": self.exec_time,
            "sim_wall_time": self.sim_wall_time,
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        d = self.as_dict()
        return "IOStats(" + ", ".join(f"{k}={v}" for k, v in d.items()) + ")"
