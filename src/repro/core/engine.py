"""Backward-compatibility shim — the engines now live in :mod:`repro.engines`.

The former monolith was split across a real storage layer:

* :mod:`repro.io` — :class:`WalkPool` backends (memory/disk walk pools using
  the 128-bit packed record) and :class:`BlockStore` (LRU resident-block
  cache + background prefetch);
* :mod:`repro.engines` — :class:`BiBlockEngine`, :class:`PlainBucketEngine`,
  :class:`SOGWEngine`, :class:`InMemoryWalker` atop that layer.

Import from those packages in new code; this module keeps every public (and
historically semi-public) name importable from ``repro.core.engine``.
"""

from repro.engines import (  # noqa: F401
    BiBlockEngine,
    EngineBase,
    InMemoryWalker,
    PlainBucketEngine,
    ResidentPair,
    SOGWEngine,
    WalkResult,
    _DeviceBlockPair,
    advance_pair,
    pair_advance_impl,
    pow2_pad,
)
from repro.engines.base import EngineBase as _EngineBase  # noqa: F401
from repro.engines.step import pow2_pad as _pow2_pad  # noqa: F401

__all__ = [
    "WalkResult",
    "BiBlockEngine",
    "EngineBase",
    "PlainBucketEngine",
    "ResidentPair",
    "SOGWEngine",
    "InMemoryWalker",
    "advance_pair",
    "pair_advance_impl",
    "pow2_pad",
    "_DeviceBlockPair",
    "_EngineBase",
    "_pow2_pad",
]
