"""Walk execution engines.

* :class:`BiBlockEngine` — the paper's system (GraSorw): triangular bi-block
  scheduling (§4.2), skewed walk storage + bucket management (§4.3),
  bucket-extending (Alg. 2), learning-based block loading (§5).
* :class:`PlainBucketEngine` — the PB baseline of §7.3 (buckets, two block
  slots, but traditional walk storage, state-aware current scheduling and a
  0..N_B-1 ancillary sweep).
* :class:`SOGWEngine` — Second-Order GraphWalker baseline (§7.1): one current
  block, per-walk random vertex I/O for the previous vertex's adjacency; with
  ``static_cache`` it becomes SGSC (static top-degree vertex cache).
* :class:`InMemoryWalker` — whole-graph fast path: the oracle for correctness
  tests and the corpus generator for LM training on small/medium graphs.

The inner step of every engine is the same batched sampler: alias/uniform
proposal + Node2vec rejection test with binary-search membership
(:mod:`repro.core.sampling`); the Pallas kernel in
:mod:`repro.kernels.node2vec_step` is the TPU version of exactly this loop.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .buckets import split_into_buckets
from .graph import BlockedGraph, ResidentBlock, block_of
from .loader import BlockLoadingModel
from .scheduler import make_scheduler
from .stats import SSD, DevicePreset, IOStats
from .transition import Node2vec, WalkTask
from .walk import WALK_BYTES, WalkBatch

__all__ = [
    "WalkResult",
    "BiBlockEngine",
    "PlainBucketEngine",
    "SOGWEngine",
    "InMemoryWalker",
]


# ===========================================================================
# The jitted pair-advance step (shared by BiBlock / PB engines)
# ===========================================================================

def pair_advance_impl(
    pair_start,      # [2] i32 — global first-vertex of each resident block
    pair_nverts,     # [2] i32
    indptr,          # [2, MV+1] i32 (block-local offsets)
    indices,         # [2, ME]   i32 (global ids, sorted per row)
    alias_j,         # [2, ME]   i32 (local alias slots; dummy if not has_alias)
    alias_q,         # [2, ME]   f32
    prev,            # [N] i32
    cur,             # [N] i32
    hop,             # [N] i32
    alive,           # [N] bool — not yet terminated
    key,             # PRNG key
    length,          # () i32 — walk length in edges
    decay,           # () f32 — per-step continue probability (1.0 = fixed len)
    p,               # () f32 — node2vec return parameter
    q,               # () f32 — node2vec in-out parameter
    *,
    order: int,
    k_max: int,
    n_iters: int,
    record: bool,
    has_alias: bool,
    max_len: int,
):
    """Advance every walk until it leaves the resident pair or terminates.

    Vectorised Alg. 2 ``UpdateWalk``: "walks keep moving while they jump
    between the two blocks in memory".  Returns
    ``(prev, cur, hop, alive, steps_taken, trace)`` where ``trace[n, h]`` is
    the vertex walk n reached at hop h during this call (-1 = no move).
    """
    N = prev.shape[0]
    ME = indices.shape[1]
    flat_indices = indices.reshape(-1)
    flat_alias_j = alias_j.reshape(-1)
    flat_alias_q = alias_q.reshape(-1)
    max_bias = jnp.maximum(1.0, jnp.maximum(1.0 / p, 1.0 / q))
    # one spare "dump" column (max_len+1) absorbs writes of frozen walks
    trace0 = jnp.full((N, max_len + 2) if record else (1, 1), -1, dtype=jnp.int32)
    iota = jnp.arange(N)

    def in_pair(v):
        return ((v >= pair_start[0]) & (v < pair_start[0] + pair_nverts[0])) | (
            (v >= pair_start[1]) & (v < pair_start[1] + pair_nverts[1])
        )

    def locate(v):
        in0 = (v >= pair_start[0]) & (v < pair_start[0] + pair_nverts[0])
        slot = jnp.where(in0, 0, 1).astype(jnp.int32)
        row = jnp.clip(v - pair_start[slot], 0, indptr.shape[1] - 2)
        return slot, row

    def cond(state):
        _, _, _, _, resident, _, _, _, it = state
        return jnp.any(resident) & (it <= max_len)

    def body(state):
        prev_, cur_, hop_, alive_, resident, key_, steps_, trace_, it = state
        key_, k_prop, k_term = jax.random.split(key_, 3)

        movable = resident  # alive & cur in pair
        slot, row = locate(cur_)
        row_start = indptr[slot, row]
        deg = indptr[slot, row + 1] - row_start
        dead = movable & (deg <= 0)
        movable = movable & (deg > 0)
        deg_c = jnp.maximum(deg, 1)

        if order == 2:
            uslot, urow = locate(prev_)
            u_start = indptr[uslot, urow]
            ulo = uslot * ME + u_start
            uhi = ulo + (indptr[uslot, urow + 1] - u_start)

        # ---- proposal + rejection over k_max rounds -------------------------
        def propose(kk, carry):
            z_, accepted_, key_p = carry
            key_p, k1 = jax.random.split(key_p)
            u123 = jax.random.uniform(k1, (3, N))
            kloc = jnp.minimum((u123[0] * deg_c).astype(jnp.int32), deg_c - 1)
            idx = slot * ME + row_start + kloc
            if has_alias:
                take_alias = u123[1] >= flat_alias_q[idx]
                kloc = jnp.where(take_alias, flat_alias_j[idx], kloc)
                idx = slot * ME + row_start + kloc
            zk = flat_indices[idx]
            if order == 2:
                from .sampling import searchsorted_rows

                memb = searchsorted_rows(flat_indices, ulo, uhi, zk, n_iters=n_iters)
                bias = jnp.where(zk == prev_, 1.0 / p, jnp.where(memb, 1.0, 1.0 / q))
                acc_p = bias / max_bias
                acc_p = jnp.where(hop_ == 0, 1.0, acc_p)  # first step: 1st-order
            else:
                acc_p = jnp.ones((N,), jnp.float32)
            last = kk == k_max - 1
            take = (~accepted_) & movable & ((u123[2] < acc_p) | last)
            z_ = jnp.where(take, zk, z_)
            return z_, accepted_ | take, key_p

        z, _, _ = jax.lax.fori_loop(0, k_max, propose, (cur_, ~movable, k_prop))

        # ---- commit ----------------------------------------------------------
        new_hop = hop_ + movable.astype(jnp.int32)
        new_prev = jnp.where(movable, cur_, prev_)
        new_cur = jnp.where(movable, z, cur_)
        finished = movable & (new_hop >= length)
        stopped = movable & (jax.random.uniform(k_term, (N,)) >= decay)
        new_alive = alive_ & ~dead & ~finished & ~stopped
        new_resident = new_alive & in_pair(new_cur)
        if record:
            cols = jnp.where(movable, jnp.clip(new_hop, 0, max_len), max_len + 1)
            trace_ = trace_.at[iota, cols].set(new_cur)
        steps_ = steps_ + movable.astype(jnp.int32).sum()
        return (new_prev, new_cur, new_hop, new_alive, new_resident, key_,
                steps_, trace_, it + 1)

    resident0 = alive & in_pair(cur)
    init = (prev, cur, hop, alive, resident0, key,
            jnp.zeros((), jnp.int32), trace0, jnp.zeros((), jnp.int32))
    prev_f, cur_f, hop_f, alive_f, _, _, steps, trace, _ = jax.lax.while_loop(
        cond, body, init
    )
    if record:
        trace = trace[:, : max_len + 1]
    return prev_f, cur_f, hop_f, alive_f, steps, trace


#: jitted entry point (host engines); the raw impl is reused inside shard_map
advance_pair = partial(
    jax.jit,
    static_argnames=("order", "k_max", "n_iters", "record", "has_alias", "max_len"),
)(pair_advance_impl)


def _pow2_pad(n: int, lo: int = 256) -> int:
    m = lo
    while m < n:
        m <<= 1
    return m


# ===========================================================================
# Shared engine plumbing
# ===========================================================================

@dataclasses.dataclass
class WalkResult:
    """Task output: endpoint histogram (PPR estimator), optional corpus."""

    num_walks: int
    steps_sampled: int
    endpoint_counts: np.ndarray  # [V] visits at termination
    corpus: Optional[np.ndarray]  # [num_walks, length+1] int32 or None
    stats: IOStats
    loader_summary: Optional[dict] = None

    def ppr_estimate(self) -> np.ndarray:
        tot = max(self.endpoint_counts.sum(), 1)
        return self.endpoint_counts / tot


class _DeviceBlockPair:
    """Two resident block slots as stacked device arrays ("memory")."""

    def __init__(self, bg: BlockedGraph, has_alias: bool):
        self.bg = bg
        self.has_alias = has_alias
        shape_ip = (2, bg.max_block_verts + 1)
        shape_ix = (2, bg.max_block_edges)
        self.start = np.zeros(2, np.int32)
        self.nverts = np.zeros(2, np.int32)
        self.indptr = np.zeros(shape_ip, np.int32)
        self.indices = np.full(shape_ix, -1, np.int32)
        self.alias_j = np.zeros(shape_ix, np.int32)
        self.alias_q = np.ones(shape_ix, np.float32)

    def set_slot(self, s: int, blk: ResidentBlock) -> None:
        self.start[s] = blk.start
        self.nverts[s] = blk.nverts
        self.indptr[s] = blk.indptr
        self.indices[s] = blk.indices
        if self.has_alias and blk.alias_j is not None:
            self.alias_j[s] = blk.alias_j
            self.alias_q[s] = blk.alias_q

    def device_args(self):
        return (
            jnp.asarray(self.start),
            jnp.asarray(self.nverts),
            jnp.asarray(self.indptr),
            jnp.asarray(self.indices),
            jnp.asarray(self.alias_j),
            jnp.asarray(self.alias_q),
        )


class _EngineBase:
    """Common state: walk pools ("disk"), stats, task bookkeeping."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        k_max: int = 16,
        pool_flush_walks: int = 1 << 18,
        seed: Optional[int] = None,
    ):
        self.bg = bg
        self.task = task
        self.stats = IOStats(preset)
        self.record_walks = record_walks
        self.k_max = k_max if isinstance(task.model, Node2vec) else 1
        if isinstance(task.model, Node2vec) and task.model.p == task.model.q == 1.0:
            self.k_max = 1  # acceptance prob is exactly 1 — no rejection needed
        self.pool_flush_walks = pool_flush_walks
        self.seed = task.seed if seed is None else seed
        self.order = task.model.order
        self.has_alias = bg.graph.weights is not None
        if self.has_alias:
            bg._build_alias = True
        self.n_iters = int(np.ceil(np.log2(max(bg.max_block_edges, 2)))) + 2
        self._key = jax.random.PRNGKey(self.seed)
        V = bg.graph.num_vertices
        self.endpoint_counts = np.zeros(V, np.int64)
        src = task.initial_walks(V)
        self.num_walks = src.shape[0]
        self.corpus = (
            np.full((self.num_walks, task.length + 1), -1, np.int32)
            if record_walks
            else None
        )
        if record_walks:
            self.corpus[:, 0] = src
        # pools: block -> list of (WalkBatch, wid array). "disk" tier.
        self.pools: Dict[int, List[Tuple[WalkBatch, np.ndarray]]] = {
            b: [] for b in range(bg.num_blocks)
        }
        self.pool_counts = np.zeros(bg.num_blocks, np.int64)
        self.pool_min_hop = np.full(bg.num_blocks, np.inf)
        self._pending_init_src = src
        self.unfinished = self.num_walks
        self.pair = _DeviceBlockPair(bg, self.has_alias)

    # -- pool plumbing ("disk" walk I/O) --------------------------------------
    def _push_pool(self, b: int, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        self.pools[b].append((batch, wid))
        self.pool_counts[b] += len(batch)
        if len(batch):
            self.pool_min_hop[b] = min(self.pool_min_hop[b], float(batch.hop.min()))
        self.stats.walk_io(len(batch))  # flush to the walk pool on disk

    def _load_pool(self, b: int) -> Tuple[WalkBatch, np.ndarray]:
        entries = self.pools[b]
        self.pools[b] = []
        n = int(self.pool_counts[b])
        self.pool_counts[b] = 0
        self.pool_min_hop[b] = np.inf
        if not entries:
            return WalkBatch.empty(), np.zeros(0, np.int64)
        batch = WalkBatch.concat([e[0] for e in entries])
        wid = np.concatenate([e[1] for e in entries])
        self.stats.walk_io(n)  # load from the walk pool on disk
        return batch, wid

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- termination bookkeeping ----------------------------------------------
    def _retire(self, batch: WalkBatch, wid: np.ndarray, alive: np.ndarray) -> Tuple[WalkBatch, np.ndarray]:
        done = ~alive
        if done.any():
            ends = batch.cur[done]
            np.add.at(self.endpoint_counts, ends, 1)
            self.unfinished -= int(done.sum())
        keep = alive
        return batch.select(keep), wid[keep]

    def _record_trace(self, wid: np.ndarray, trace: np.ndarray) -> None:
        if self.corpus is None or wid.size == 0:
            return
        cols = np.nonzero((trace >= 0).any(axis=0))[0]
        for h in cols:
            col = trace[:, h]
            m = col >= 0
            self.corpus[wid[m], h] = col[m]

    # -- the jitted advance wrapper --------------------------------------------
    def _advance(self, batch: WalkBatch, wid: np.ndarray):
        """Run advance_pair on the resident pair; returns updated host batch."""
        n = len(batch)
        N = _pow2_pad(n)
        pad = N - n

        def pad32(x, fill):
            return jnp.asarray(
                np.concatenate([x.astype(np.int32), np.full(pad, fill, np.int32)])
            )

        prev = pad32(batch.prev, 0)
        cur = pad32(batch.cur, 0)
        hop = pad32(batch.hop, 0)
        alive = jnp.asarray(
            np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
        )
        t0 = time.perf_counter()
        out = advance_pair(
            *self.pair.device_args(),
            prev, cur, hop, alive, self._next_key(),
            jnp.int32(self.task.length), jnp.float32(self.task.decay),
            jnp.float32(getattr(self.task.model, "p", 1.0)),
            jnp.float32(getattr(self.task.model, "q", 1.0)),
            order=self.order, k_max=self.k_max, n_iters=self.n_iters,
            record=self.record_walks, has_alias=self.has_alias,
            max_len=int(self.task.length),
        )
        prev_f, cur_f, hop_f, alive_f, steps, trace = jax.tree.map(
            np.asarray, jax.block_until_ready(out)
        )
        self.stats.exec_time += time.perf_counter() - t0
        self.stats.steps_sampled += int(steps)
        if self.record_walks:
            self._record_trace(wid, trace[:n])
        new_batch = WalkBatch(batch.src, prev_f[:n], cur_f[:n], hop_f[:n])
        return new_batch, alive_f[:n]

    # -- initialization stage (paper App. B step 1) -----------------------------
    def _initialize(self) -> None:
        """First-order init: advance walks inside their source block until
        they leave it or terminate, guaranteeing B(u) != B(v) for every
        persisted walk."""
        src = self._pending_init_src
        self._pending_init_src = None
        wid_all = np.arange(src.shape[0], dtype=np.int64)
        src_blocks = block_of(self.bg.block_starts, src)
        for b in np.unique(src_blocks):
            blk = self.bg.materialize_block(int(b))
            self.stats.block_load(int(b), blk.nbytes_full(), sequential=True)
            self.pair.set_slot(0, blk)
            self.pair.set_slot(1, blk)
            m = src_blocks == b
            batch = WalkBatch(src[m], src[m], src[m], np.zeros(m.sum(), np.int32))
            wid = wid_all[m]
            batch, alive = self._advance(batch, wid)
            batch, wid = self._retire(batch, wid, alive)
            self._persist(batch, wid)

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        raise NotImplementedError

    def result(self) -> WalkResult:
        return WalkResult(
            num_walks=self.num_walks,
            steps_sampled=self.stats.steps_sampled,
            endpoint_counts=self.endpoint_counts,
            corpus=self.corpus,
            stats=self.stats,
        )


# ===========================================================================
# GraSorw: the bi-block engine
# ===========================================================================

class BiBlockEngine(_EngineBase):
    """Triangular bi-block scheduling + skewed storage + buckets + LBL."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        loading: str = "auto",
        bucket_extending: bool = True,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.loader = BlockLoadingModel(bg.num_blocks, mode=loading)
        self.bucket_extending = bucket_extending

    # skewed storage: persist with min(B(u), B(v)); first-order models never
    # read prev, so they use the traditional B(cur) association (§7.8)
    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        if self.order == 1:
            assoc = block_of(self.bg.block_starts, batch.cur)
        else:
            assoc = np.minimum(
                block_of(self.bg.block_starts, batch.prev),
                block_of(self.bg.block_starts, batch.cur),
            )
        for b in np.unique(assoc):
            m = assoc == b
            self._push_pool(int(b), batch.select(m), wid[m])

    #: modelled in-memory cost per sampled step (feeds the LR exec component)
    STEP_COST = 2.0e-8

    def _load_ancillary(self, i: int, n_bucket_walks: int, activated: np.ndarray):
        """Load block i with the learned method; meter; return (decision,
        eta, load_cost) — execution cost is added before feeding the model
        (the paper's t_f / t_o cover loading *and* executing, §5.2.1)."""
        blk = self.bg.materialize_block(i)
        nv = int(self.bg.block_nverts[i])
        decision = self.loader.choose(i, n_bucket_walks, nv)
        eta = n_bucket_walks / max(nv, 1)
        if decision == "full":
            nbytes = blk.nbytes_full()
            cost = self.stats.preset.seq_cost(nbytes)
            self.stats.block_load(i, nbytes, sequential=True)
        else:
            nbytes = self.bg.activated_load_bytes(activated)
            n_act = np.unique(activated).size
            cost = self.stats.preset.rand_cost(n_act, nbytes)
            self.stats.ondemand_load(n_act, nbytes)
        self.pair.set_slot(1, blk)
        return decision, eta, cost

    def _meter_extension(self, i: int, batch_before: WalkBatch, batch_after: WalkBatch) -> float:
        """On-demand loads gather extension vertices reached mid-advance.
        Returns the modelled cost of those gathers."""
        s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
        touched = batch_after.cur[(batch_after.cur >= s) & (batch_after.cur < e)]
        pre = np.unique(
            np.concatenate(
                [
                    batch_before.cur[(batch_before.cur >= s) & (batch_before.cur < e)],
                    batch_before.prev[(batch_before.prev >= s) & (batch_before.prev < e)],
                ]
            )
        )
        ext = np.setdiff1d(np.unique(touched), pre, assume_unique=False)
        if ext.size:
            nbytes = self.bg.activated_load_bytes(ext)
            self.stats.ondemand_load(ext.size, nbytes)
            return self.stats.preset.rand_cost(ext.size, nbytes)
        return 0.0

    def run(self) -> WalkResult:
        if self.order == 1:
            return self._run_first_order()
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB - 1):
                if self.pool_counts[b] == 0:
                    continue
                batch, wid = self._load_pool(b)
                self.stats.time_slots += 1
                blk_b = self.bg.materialize_block(b)
                self.stats.block_load(b, blk_b.nbytes_full(), sequential=True)
                self.pair.set_slot(0, blk_b)
                buckets = split_into_buckets(self.bg.block_starts, batch, b)
                wid_buckets: Dict[int, np.ndarray] = {}
                # rebuild wid alignment: split_into_buckets sorted the batch,
                # so recompute per-bucket ids the same way
                from .buckets import bucket_ids as _bids

                ids = _bids(self.bg.block_starts, batch, b)
                order = np.argsort(ids, kind="stable")
                ids_sorted = ids[order]
                wid_sorted = wid[order]
                uniq, starts = np.unique(ids_sorted, return_index=True)
                bounds = list(starts) + [len(batch)]
                for k, bid in enumerate(uniq):
                    wid_buckets[int(bid)] = wid_sorted[bounds[k] : bounds[k + 1]]

                i = b  # ancillary cursor: strictly increasing (triangular)
                pending = dict(buckets)
                while True:
                    remaining = sorted(k for k in pending if k > i)
                    if not remaining:
                        break
                    i = remaining[0]
                    bucket = pending.pop(i)
                    bwid = wid_buckets.pop(i)
                    self.stats.bucket_executions += 1
                    activated = np.concatenate([bucket.prev, bucket.cur])
                    s, e = self.bg.block_starts[i], self.bg.block_starts[i + 1]
                    activated = activated[(activated >= s) & (activated < e)]
                    decision, eta, cost = self._load_ancillary(i, len(bucket), activated)
                    before = bucket
                    steps_before = self.stats.steps_sampled
                    bucket, alive = self._advance(bucket, bwid)
                    if decision == "ondemand":
                        cost += self._meter_extension(i, before, bucket)
                    cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                    self.loader.observe(i, eta, cost, decision)
                    bucket, bwid = self._retire(bucket, bwid, alive)
                    if len(bucket) == 0:
                        continue
                    # Alg. 2 routing
                    pre_blk = block_of(self.bg.block_starts, bucket.prev)
                    cur_blk = block_of(self.bg.block_starts, bucket.cur)
                    extend = (
                        (cur_blk > i) & (pre_blk == b)
                        if self.bucket_extending
                        else np.zeros(len(bucket), bool)
                    )
                    # persist the non-extending walks with min-rule
                    self._persist(bucket.select(~extend), bwid[~extend])
                    if extend.any():
                        ext_batch = bucket.select(extend)
                        ext_wid = bwid[extend]
                        for nb in np.unique(cur_blk[extend]):
                            m = cur_blk[extend] == nb
                            nb = int(nb)
                            if nb in pending:
                                pending[nb] = WalkBatch.concat(
                                    [pending[nb], ext_batch.select(m)]
                                )
                                wid_buckets[nb] = np.concatenate(
                                    [wid_buckets[nb], ext_wid[m]]
                                )
                            else:
                                pending[nb] = ext_batch.select(m)
                                wid_buckets[nb] = ext_wid[m]
        res = self.result()
        res.loader_summary = self.loader.summary()
        return res

    def _run_first_order(self) -> WalkResult:
        """§7.8: first-order walks need only the current block; iteration
        scheduling + the learning-based loader on the current block itself
        ("heavy block loads become light vertex I/Os once few walks remain")."""
        self._initialize()
        NB = self.bg.num_blocks
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * NB + 10:
                raise RuntimeError("engine failed to converge (bug)")
            self.stats.supersteps += 1
            for b in range(NB):
                if self.pool_counts[b] == 0:
                    continue
                batch, wid = self._load_pool(b)
                self.stats.time_slots += 1
                self.stats.bucket_executions += 1
                activated = batch.cur
                decision, eta, cost = self._load_ancillary(b, len(batch), activated)
                self.pair.set_slot(0, self.bg.materialize_block(b))
                before = batch
                steps_before = self.stats.steps_sampled
                batch, alive = self._advance(batch, wid)
                if decision == "ondemand":
                    cost += self._meter_extension(b, before, batch)
                cost += self.STEP_COST * (self.stats.steps_sampled - steps_before)
                self.loader.observe(b, eta, cost, decision)
                batch, wid = self._retire(batch, wid, alive)
                self._persist(batch, wid)
        res = self.result()
        res.loader_summary = self.loader.summary()
        return res


# ===========================================================================
# PB baseline: buckets without triangular scheduling / skewed storage
# ===========================================================================

class PlainBucketEngine(_EngineBase):
    """§7.3 baseline: traditional walk storage (B(cur)), state-aware current
    scheduling (GraphWalker's max-sum), ancillary sweep b0..b_{N_B-1}."""

    def __init__(self, bg: BlockedGraph, task: WalkTask, *, preset: DevicePreset = SSD,
                 record_walks: bool = False, **kw):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.scheduler = make_scheduler("max_sum", bg.num_blocks, self.seed)

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        assoc = block_of(self.bg.block_starts, batch.cur)
        for b in np.unique(assoc):
            m = assoc == b
            self._push_pool(int(b), batch.select(m), wid[m])

    def run(self) -> WalkResult:
        self._initialize()
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * self.bg.num_blocks * 4 + 10:
                raise RuntimeError("engine failed to converge (bug)")
            b = self.scheduler.next_block(self.pool_counts, self.pool_min_hop)
            if b is None:
                break
            batch, wid = self._load_pool(b)
            if len(batch) == 0:
                continue
            self.stats.time_slots += 1
            self.stats.supersteps += 1
            blk_b = self.bg.materialize_block(b)
            # state-aware scheduling jumps around: current block load is a
            # random block I/O (the paper's point about sequential wins)
            self.stats.block_load(b, blk_b.nbytes_full(), sequential=False)
            self.pair.set_slot(0, blk_b)
            # walks live with B(cur); bucket key = B(prev) (plain bucketing)
            pre_blk = block_of(self.bg.block_starts, batch.prev)
            for i in range(self.bg.num_blocks):
                m = pre_blk == i
                if not m.any():
                    continue
                bucket, bwid = batch.select(m), wid[m]
                self.stats.bucket_executions += 1
                blk_i = self.bg.materialize_block(i)
                seq = i == b + 1  # only the successor read is sequential
                self.stats.block_load(i, blk_i.nbytes_full(), sequential=seq)
                self.pair.set_slot(1, blk_i)
                bucket, alive = self._advance(bucket, bwid)
                bucket, bwid = self._retire(bucket, bwid, alive)
                self._persist(bucket, bwid)
        return self.result()


# ===========================================================================
# SOGW / SGSC baselines (host-side; per-walk vertex I/O accounting)
# ===========================================================================

class SOGWEngine(_EngineBase):
    """Second-order GraphWalker: one current block; every walk whose stored
    previous vertex lies outside it pays a random vertex I/O (the paper's
    Fig. 1a bottleneck).  ``static_cache=True`` adds SGSC's top-degree cache
    sized to one block's edge budget."""

    def __init__(
        self,
        bg: BlockedGraph,
        task: WalkTask,
        *,
        static_cache: bool = False,
        preset: DevicePreset = SSD,
        record_walks: bool = False,
        **kw,
    ):
        super().__init__(bg, task, preset=preset, record_walks=record_walks, **kw)
        self.scheduler = make_scheduler("max_sum", bg.num_blocks, self.seed)
        self.cached = np.zeros(bg.graph.num_vertices, bool)
        if static_cache:
            deg = bg.graph.degrees.astype(np.int64)
            order = np.argsort(-deg)
            budget = int(bg.block_nedges.max())
            csum = np.cumsum(deg[order])
            k = int(np.searchsorted(csum, budget, side="right"))
            top = order[: max(k, 1)]
            self.cached[top] = True
            # cache initialisation is I/O (the paper charges it to I/O time)
            self.stats.vertex_load(top.size, int(8 * top.size + 4 * deg[top].sum()))

    def _persist(self, batch: WalkBatch, wid: np.ndarray) -> None:
        if len(batch) == 0:
            return
        assoc = block_of(self.bg.block_starts, batch.cur)
        for b in np.unique(assoc):
            m = assoc == b
            self._push_pool(int(b), batch.select(m), wid[m])

    def run(self) -> WalkResult:
        self._initialize()
        guard = 0
        while self.unfinished > 0:
            guard += 1
            if guard > self.task.length * self.bg.num_blocks * 4 + 10:
                raise RuntimeError("engine failed to converge (bug)")
            b = self.scheduler.next_block(self.pool_counts, self.pool_min_hop)
            if b is None:
                break
            batch, wid = self._load_pool(b)
            if len(batch) == 0:
                continue
            self.stats.time_slots += 1
            self.stats.supersteps += 1
            blk_b = self.bg.materialize_block(b)
            self.stats.block_load(b, blk_b.nbytes_full(), sequential=False)
            # vertex I/Os: SECOND-order walks must fetch the stored previous
            # vertex's adjacency when it lies outside the current block
            # (first-order models never touch prev — paper Fig. 1a)
            pre_blk = block_of(self.bg.block_starts, batch.prev)
            needs_io = (
                (pre_blk != b) & (batch.hop > 0) & ~self.cached[batch.prev]
                if self.order == 2
                else np.zeros(len(batch), bool)
            )
            if needs_io.any():
                vs = batch.prev[needs_io]
                deg = self.bg.graph.degrees[vs].astype(np.int64)
                # per-walk light I/O — SOGW does not dedupe across walks
                self.stats.vertex_load(int(needs_io.sum()), int(8 * needs_io.sum() + 4 * deg.sum()))
            # advance within the single block: resident pair = (b, b)
            self.pair.set_slot(0, blk_b)
            self.pair.set_slot(1, blk_b)
            batch, alive = self._advance(batch, wid)
            batch, wid = self._retire(batch, wid, alive)
            self._persist(batch, wid)
        return self.result()


# ===========================================================================
# In-memory oracle / corpus generator
# ===========================================================================

class InMemoryWalker:
    """Whole-graph walker: one jit'd while_loop over steps.  Ground truth for
    engine tests and the corpus generator feeding the LM data pipeline."""

    def __init__(self, bg: BlockedGraph, task: WalkTask, *, k_max: int = 16):
        self.bg = bg
        self.task = task
        self.k_max = 1 if (isinstance(task.model, Node2vec)
                           and task.model.p == task.model.q == 1.0) else k_max
        if task.model.order == 1:
            self.k_max = 1

    def run(self, *, record_walks: bool = True) -> WalkResult:
        bg, task = self.bg, self.task
        g = bg.graph
        stats = IOStats()
        src = task.initial_walks(g.num_vertices)
        n = src.shape[0]
        # whole graph as a single resident "pair" (slot 1 unused)
        indptr = np.zeros((2, g.num_vertices + 1), np.int32)
        indptr[0] = g.indptr.astype(np.int32)
        indptr[1] = 0
        indices = np.full((2, max(g.num_edges, 1)), -1, np.int32)
        indices[0, : g.num_edges] = g.indices
        pair_start = np.array([0, g.num_vertices], np.int32)
        pair_nverts = np.array([g.num_vertices, 0], np.int32)
        has_alias = g.weights is not None
        if has_alias:
            from .sampling import build_alias_rows

            aj, aq = build_alias_rows(
                indptr[0], g.num_vertices, max(g.num_edges, 1), g.weights
            )
            alias_j = np.stack([aj, aj])
            alias_q = np.stack([aq, aq])
        else:
            alias_j = np.zeros_like(indices)
            alias_q = np.ones(indices.shape, np.float32)

        N = _pow2_pad(n)
        pad = N - n
        pad32 = lambda x: jnp.asarray(
            np.concatenate([x.astype(np.int32), np.zeros(pad, np.int32)])
        )
        alive = jnp.asarray(np.concatenate([np.ones(n, bool), np.zeros(pad, bool)]))
        t0 = time.perf_counter()
        out = advance_pair(
            jnp.asarray(pair_start), jnp.asarray(pair_nverts),
            jnp.asarray(indptr), jnp.asarray(indices),
            jnp.asarray(alias_j), jnp.asarray(alias_q),
            pad32(src), pad32(src), pad32(np.zeros(n)), alive,
            jax.random.PRNGKey(task.seed),
            jnp.int32(task.length), jnp.float32(task.decay),
            jnp.float32(getattr(task.model, "p", 1.0)),
            jnp.float32(getattr(task.model, "q", 1.0)),
            order=task.model.order, k_max=self.k_max,
            n_iters=int(np.ceil(np.log2(max(g.num_edges, 2)))) + 2,
            record=record_walks, has_alias=has_alias, max_len=int(task.length),
        )
        prev_f, cur_f, hop_f, alive_f, steps, trace = jax.tree.map(
            np.asarray, jax.block_until_ready(out)
        )
        stats.exec_time = time.perf_counter() - t0
        stats.steps_sampled = int(steps)
        counts = np.bincount(cur_f[:n], minlength=g.num_vertices).astype(np.int64)
        corpus = None
        if record_walks:
            corpus = np.full((n, task.length + 1), -1, np.int32)
            corpus[:, 0] = src
            t = trace[:n]
            for h in range(1, task.length + 1):
                m = t[:, h] >= 0
                corpus[m, h] = t[m, h]
        return WalkResult(n, int(steps), counts, corpus, stats)
