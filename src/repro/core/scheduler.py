"""Block scheduling strategies (paper §4.1/§4.2 + Appendix A).

The minimal-current-block-I/O problem is NP-hard (reduction from shortest
common supersequence, Thm. 1), and the block access sequence of a walk is
only revealed online, so the paper adopts heuristics.  We implement every
strategy from Appendix A — they drive the baseline engines and the Table-8
benchmark — and the triangular pair schedule (Eq. 3) used by the bi-block
engine.
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

__all__ = [
    "TimeSlotPlan",
    "triangular_pairs",
    "triangular_block_io_bound",
    "standard_block_io_bound",
    "CurrentBlockScheduler",
    "AlphabetScheduler",
    "IterationScheduler",
    "MinHeightScheduler",
    "MaxSumScheduler",
    "GraphWalkerScheduler",
    "make_scheduler",
]


def triangular_pairs(num_blocks: int) -> Iterator[tuple[int, list[int]]]:
    """Yield (current block b, ancillary ids b+1..N_B-1) — Alg. 1 lines 2/13."""
    for b in range(num_blocks - 1):
        yield b, list(range(b + 1, num_blocks))


class TimeSlotPlan:
    """The triangular slot order (Eq. 3) as an explicit, queryable plan.

    One *slot* is the execution of one current block within a superstep.
    Second-order tasks visit ``b = 0 .. N_B-2`` (the last block never owns a
    skewed pool: ``min(B(u), B(v)) < N_B-1`` whenever the pair spans blocks);
    first-order tasks visit every block (traditional ``B(cur)`` association,
    §7.8).  The plan is what the async bucket pipeline schedules from: it
    names the *next* slot (including the wrap into the next superstep) before
    the current one finishes, so the next slot's pool drain, bucket split and
    current-view load can start on background workers.  The plan is static;
    which slots actually *run* stays a property of the live pool counts, so
    planning can never change what executes.
    """

    def __init__(self, num_blocks: int, order: int = 2):
        self.num_blocks = num_blocks
        self.order = order
        last = num_blocks if order == 1 else max(num_blocks - 1, 1)
        self.slot_blocks = tuple(range(last))

    def slots(self) -> Iterator[int]:
        """Current-block ids of one superstep, in triangular order."""
        return iter(self.slot_blocks)

    def ancillary_after(self, b: int) -> range:
        """Ancillary block ids a slot on ``b`` may visit (strictly increasing
        bucket cursor, Alg. 1)."""
        return range(b + 1, self.num_blocks)

    def next_slot(self, b: int, has_walks) -> Optional[int]:
        """The next slot after ``b`` that currently has walks pending, probing
        the rest of this superstep first, then wrapping into the next one.

        ``has_walks(block) -> bool`` queries live state (pool counts plus any
        already-preloaded batches); a block that only *gains* walks after this
        call is simply picked later — a missed overlap, never a missed slot.
        """
        n = len(self.slot_blocks)
        for k in range(1, n + 1):
            cand = self.slot_blocks[(b + k) % n]
            if has_walks(cand):
                return cand
        return None


def triangular_block_io_bound(num_blocks: int) -> int:
    """Eq. 3: N_B - 1 + sum_{b=0}^{N_B-2} (N_B - 1 - b) = (N_B+2)(N_B-1)/2."""
    n = num_blocks
    return (n + 2) * (n - 1) // 2


def standard_block_io_bound(num_blocks: int) -> int:
    """Eq. 2: N_B + N_B (N_B - 1) = N_B^2."""
    return num_blocks * num_blocks


class CurrentBlockScheduler:
    """Chooses the next *current* block given per-block walk statistics.

    ``walk_counts[b]`` — number of stored walks whose pool is block b;
    ``min_hops[b]`` — smallest hop among them (inf when empty).
    """

    name = "base"

    def __init__(self, num_blocks: int, seed: int = 0):
        self.num_blocks = num_blocks
        self.rng = np.random.default_rng(seed)
        self.cursor = -1

    def next_block(self, walk_counts: np.ndarray, min_hops: np.ndarray) -> Optional[int]:
        raise NotImplementedError


class AlphabetScheduler(CurrentBlockScheduler):
    """b0..b_{N_B-1} cyclically, visiting empty blocks too (approx ratio N_B)."""

    name = "alphabet"

    def next_block(self, walk_counts, min_hops):
        if walk_counts.sum() == 0:
            return None
        self.cursor = (self.cursor + 1) % self.num_blocks
        return self.cursor


class IterationScheduler(CurrentBlockScheduler):
    """The paper's choice: Alphabet but skipping empty blocks."""

    name = "iteration"

    def next_block(self, walk_counts, min_hops):
        if walk_counts.sum() == 0:
            return None
        for _ in range(self.num_blocks):
            self.cursor = (self.cursor + 1) % self.num_blocks
            if walk_counts[self.cursor] > 0:
                return self.cursor
        return None


class MinHeightScheduler(CurrentBlockScheduler):
    """Block containing the walk with the fewest steps taken."""

    name = "min_height"

    def next_block(self, walk_counts, min_hops):
        if walk_counts.sum() == 0:
            return None
        masked = np.where(walk_counts > 0, min_hops, np.inf)
        return int(np.argmin(masked))


class MaxSumScheduler(CurrentBlockScheduler):
    """Block containing the most walks (GraphWalker's state-aware pick)."""

    name = "max_sum"

    def next_block(self, walk_counts, min_hops):
        if walk_counts.sum() == 0:
            return None
        return int(np.argmax(walk_counts))


class GraphWalkerScheduler(CurrentBlockScheduler):
    """Max-Sum with prob p (=0.8, GraphWalker's setting), else Min-Height."""

    name = "graphwalker"

    def __init__(self, num_blocks: int, seed: int = 0, p: float = 0.8):
        super().__init__(num_blocks, seed)
        self.p = p
        self._max = MaxSumScheduler(num_blocks, seed)
        self._min = MinHeightScheduler(num_blocks, seed)

    def next_block(self, walk_counts, min_hops):
        if walk_counts.sum() == 0:
            return None
        pick = self._max if self.rng.random() < self.p else self._min
        return pick.next_block(walk_counts, min_hops)


_SCHEDULERS = {
    s.name: s
    for s in (
        AlphabetScheduler,
        IterationScheduler,
        MinHeightScheduler,
        MaxSumScheduler,
        GraphWalkerScheduler,
    )
}


def make_scheduler(name: str, num_blocks: int, seed: int = 0) -> CurrentBlockScheduler:
    try:
        return _SCHEDULERS[name](num_blocks, seed)
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; have {sorted(_SCHEDULERS)}")
