"""Learning-based block loading model (paper §5).

Two loading methods exist for an ancillary block:

* **full load** — stream the whole block slice (index + CSR cells);
* **on-demand load** — gather only *activated* vertices (those that are the
  ``prev``/``cur`` of some walk in the bucket), at random-I/O cost, plus a
  trickle of extension gathers during execution when a walk reaches a vertex
  that was not pre-activated.

Selection is learned online (§5.2): per block, fit

    t_f = α_f · η + b_f          (full;   intercept = pure load cost)
    t_o = α_o · η                (on-demand; no intercept — empty W is free)

over ``η = |W| / N_v`` and switch at ``η₀ = b_f / (α_o − α_f)``.  Costs fed
to the regression are the *simulated* device costs from
:mod:`repro.core.stats` so training is deterministic; the same class accepts
wall-clock samples when run on real hardware.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Literal, Optional

__all__ = ["LinearCostModel", "BlockLoadingModel", "LoadDecision"]

LoadDecision = Literal["full", "ondemand"]


@dataclasses.dataclass
class LinearCostModel:
    """Least-squares y = a·x (+ b) with online sample accumulation."""

    with_intercept: bool
    sx: float = 0.0
    sy: float = 0.0
    sxx: float = 0.0
    sxy: float = 0.0
    n: int = 0

    def add(self, x: float, y: float) -> None:
        self.sx += x
        self.sy += y
        self.sxx += x * x
        self.sxy += x * y
        self.n += 1

    def fit(self) -> tuple[float, float]:
        """Returns (a, b); b = 0 for the no-intercept model."""
        if self.n == 0:
            return 0.0, 0.0
        if not self.with_intercept:
            return (self.sxy / self.sxx if self.sxx > 0 else 0.0), 0.0
        det = self.n * self.sxx - self.sx * self.sx
        if abs(det) < 1e-18:
            return 0.0, self.sy / self.n
        a = (self.n * self.sxy - self.sx * self.sy) / det
        b = (self.sy * self.sxx - self.sx * self.sxy) / det
        return a, b


class BlockLoadingModel:
    """Per-block η-threshold selector with a global fallback model.

    Modes:
      * ``train_full`` / ``train_ondemand`` — force one method and collect
        (η, t) samples (the paper's two profiling runs);
      * ``auto`` — use learned η₀ per block (global η₀ until a block has
        enough of its own samples).
    """

    def __init__(
        self,
        num_blocks: int,
        mode: Literal["auto", "train_full", "train_ondemand", "full", "ondemand"] = "auto",
        min_samples: int = 4,
        default_eta0: float = 0.15,
    ):
        self.num_blocks = num_blocks
        self.mode = mode
        self.min_samples = min_samples
        self.default_eta0 = default_eta0
        self._full: Dict[int, LinearCostModel] = {}
        self._ond: Dict[int, LinearCostModel] = {}
        self._gfull = LinearCostModel(with_intercept=True)
        self._gond = LinearCostModel(with_intercept=False)

    # -- cost model ----------------------------------------------------------
    @staticmethod
    def ondemand_cost(
        preset,
        n_vertices: int,
        nbytes: int,
        *,
        seeks: int | None = None,
        waste_bytes: int = 0,
    ) -> float:
        """Modelled on-demand cost with the per-seek term.

        The reference path pays one random I/O per activated vertex
        (``seeks=None`` — exactly ``preset.rand_cost``).  With the gap-aware
        read planner on, cost is a function of the *coalesced ranges* the
        plan actually issued, not the raw vertex count: one seek per range
        plus streaming over useful + read-through waste bytes.  Feeding this
        to :meth:`observe` makes the learned full-vs-on-demand threshold
        η₀ reflect coalesced reality.
        """
        if seeks is None:
            return preset.rand_cost(n_vertices, nbytes)
        return seeks * preset.rand_latency + (nbytes + waste_bytes) / preset.rand_bandwidth

    # -- sample collection ---------------------------------------------------
    def observe(self, block_id: int, eta: float, cost: float, method: LoadDecision) -> None:
        if method == "full":
            self._full.setdefault(block_id, LinearCostModel(True)).add(eta, cost)
            self._gfull.add(eta, cost)
        else:
            self._ond.setdefault(block_id, LinearCostModel(False)).add(eta, cost)
            self._gond.add(eta, cost)

    # -- threshold -------------------------------------------------------------
    @staticmethod
    def _eta0(full: LinearCostModel, ond: LinearCostModel) -> Optional[float]:
        a_f, b_f = full.fit()
        a_o, _ = ond.fit()
        if a_o - a_f <= 1e-12 or b_f <= 0:
            return None
        return b_f / (a_o - a_f)

    def eta0(self, block_id: int) -> float:
        f = self._full.get(block_id)
        o = self._ond.get(block_id)
        if f is not None and o is not None and f.n >= self.min_samples and o.n >= self.min_samples:
            t = self._eta0(f, o)
            if t is not None:
                return t
        if self._gfull.n >= self.min_samples and self._gond.n >= self.min_samples:
            t = self._eta0(self._gfull, self._gond)
            if t is not None:
                return t
        return self.default_eta0

    # -- decision ---------------------------------------------------------------
    def choose(self, block_id: int, num_walks: int, block_nverts: int) -> LoadDecision:
        if self.mode in ("train_full", "full"):
            return "full"
        if self.mode in ("train_ondemand", "ondemand"):
            return "ondemand"
        eta = num_walks / max(block_nverts, 1)
        return "full" if eta > self.eta0(block_id) else "ondemand"

    def summary(self) -> dict:
        a_f, b_f = self._gfull.fit()
        a_o, _ = self._gond.fit()
        return {
            "global_alpha_f": a_f,
            "global_b_f": b_f,
            "global_alpha_o": a_o,
            "global_eta0": self._eta0(self._gfull, self._gond),
            "full_samples": self._gfull.n,
            "ondemand_samples": self._gond.n,
        }
