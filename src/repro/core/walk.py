"""Walk state — struct-of-arrays batches plus the paper's 128-bit encoding.

The engine operates on SoA numpy/jnp batches (``src, prev, cur, hop``); the
disk-resident walk pools use the paper's 128-bit packed record (§6.1, Fig. 7)
so walk-I/O byte accounting matches the paper.  Our field layout (sums to 128):

    source vertex : 36 bits   (up to ~68.7 G vertices)
    prev vertex   : 36 bits
    cur offset    : 26 bits   (offset of cur within its block)
    prev block    : 10 bits   (<= 1024 blocks, as the paper)
    cur block     : 10 bits
    hop           : 10 bits   (<= 1024 steps, as the paper)

jnp has no uint128 (and uint64 needs x64 mode) so a packed record is 4 uint32
lanes; pack/unpack are pure vector ops usable under jit.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["WalkBatch", "pack_walks", "unpack_walks", "WALK_BYTES"]

WALK_BYTES = 16

_SRC_BITS, _PREV_BITS, _CUR_BITS = 36, 36, 26
_BLK_BITS, _HOP_BITS = 10, 10


@dataclasses.dataclass
class WalkBatch:
    """SoA batch of walks (host numpy; device twins are plain dicts of jnp)."""

    src: np.ndarray  # [n] int64 — source vertex (walk identity / restart target)
    prev: np.ndarray  # [n] int64 — previous vertex u
    cur: np.ndarray  # [n] int64 — current vertex v
    hop: np.ndarray  # [n] int32 — steps taken so far

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int64)
        self.prev = np.asarray(self.prev, dtype=np.int64)
        self.cur = np.asarray(self.cur, dtype=np.int64)
        self.hop = np.asarray(self.hop, dtype=np.int32)

    def __len__(self) -> int:
        return int(self.src.shape[0])

    def select(self, mask_or_idx) -> "WalkBatch":
        return WalkBatch(
            self.src[mask_or_idx],
            self.prev[mask_or_idx],
            self.cur[mask_or_idx],
            self.hop[mask_or_idx],
        )

    @staticmethod
    def concat(batches: list["WalkBatch"]) -> "WalkBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return WalkBatch.empty()
        return WalkBatch(
            np.concatenate([b.src for b in batches]),
            np.concatenate([b.prev for b in batches]),
            np.concatenate([b.cur for b in batches]),
            np.concatenate([b.hop for b in batches]),
        )

    @staticmethod
    def empty() -> "WalkBatch":
        z64 = np.zeros(0, np.int64)
        return WalkBatch(z64, z64, z64, np.zeros(0, np.int32))


def _split_hi_lo(x: np.ndarray, lo_bits: int) -> Tuple[np.ndarray, np.ndarray]:
    return (x >> lo_bits).astype(np.uint32), (x & ((1 << lo_bits) - 1)).astype(np.uint32)


def pack_walks(batch: WalkBatch, block_starts: np.ndarray) -> np.ndarray:
    """Pack to the 128-bit record: returns uint32[n, 4].

    ``cur`` is stored as (cur_block, offset-in-block) exactly as the paper's
    Fig. 7 ("Cur Vertex is the offset of the current vertex in its residing
    block"); ``prev`` is stored as a full vertex id.
    """
    from .graph import block_of

    n = len(batch)
    src = batch.src.astype(np.uint64)
    prev = batch.prev.astype(np.uint64)
    cur_blk = block_of(block_starts, batch.cur).astype(np.uint64)
    prev_blk = block_of(block_starts, batch.prev).astype(np.uint64)
    cur_off = (batch.cur - block_starts[cur_blk.astype(np.int64)]).astype(np.uint64)
    hop = batch.hop.astype(np.uint64)

    if np.any(src >= (1 << _SRC_BITS)) or np.any(prev >= (1 << _PREV_BITS)):
        raise OverflowError("vertex id exceeds 36-bit walk encoding")
    if np.any(cur_off >= (1 << _CUR_BITS)):
        raise OverflowError("block offset exceeds 26-bit walk encoding")
    if np.any(cur_blk >= (1 << _BLK_BITS)) or np.any(hop >= (1 << _HOP_BITS)):
        raise OverflowError("block id / hop exceeds 10-bit walk encoding")

    # bit layout over a logical uint128, least significant first:
    # [hop:10][cur_blk:10][prev_blk:10][cur_off:26][prev:36][src:36]
    w = np.zeros((n, 4), dtype=np.uint64)  # 2x64 staging, then split to 4x32
    lo = hop | (cur_blk << 10) | (prev_blk << 20) | (cur_off << 30) | ((prev & 0xFF) << 56)
    hi = (prev >> 8) | (src << 28)  # 28 bits of prev + 36 bits of src = 64
    out = np.empty((n, 4), dtype=np.uint32)
    out[:, 0] = (lo & 0xFFFFFFFF).astype(np.uint32)
    out[:, 1] = (lo >> 32).astype(np.uint32)
    out[:, 2] = (hi & 0xFFFFFFFF).astype(np.uint32)
    out[:, 3] = (hi >> 32).astype(np.uint32)
    del w
    return out


def unpack_walks(packed: np.ndarray, block_starts: np.ndarray) -> WalkBatch:
    """Inverse of :func:`pack_walks`."""
    packed = np.asarray(packed, dtype=np.uint32)
    lo = packed[:, 0].astype(np.uint64) | (packed[:, 1].astype(np.uint64) << 32)
    hi = packed[:, 2].astype(np.uint64) | (packed[:, 3].astype(np.uint64) << 32)
    hop = (lo & 0x3FF).astype(np.int32)
    cur_blk = ((lo >> 10) & 0x3FF).astype(np.int64)
    cur_off = ((lo >> 30) & ((1 << 26) - 1)).astype(np.int64)
    prev = (((lo >> 56) & 0xFF) | ((hi & ((1 << 28) - 1)) << 8)).astype(np.int64)
    src = (hi >> 28).astype(np.int64)
    cur = block_starts[cur_blk] + cur_off
    return WalkBatch(src, prev, cur, hop)
