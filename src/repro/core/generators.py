"""Fast numpy graph generators for the paper's synthetic studies (Table 5).

NetworkX (used by the paper) is far too slow at benchmark scale on one core;
these produce the same families — circulant, Erdős–Rényi, Barabási–Albert,
stochastic block model, plus Graph500-style RMAT for the Kron29 analogue —
as vectorised edge-list constructions.
"""

from __future__ import annotations

import numpy as np

from .graph import CSRGraph

__all__ = [
    "circulant_graph",
    "erdos_renyi",
    "barabasi_albert",
    "stochastic_block_model",
    "rmat",
]


def circulant_graph(n: int, offsets_count: int) -> CSRGraph:
    """CirculantG: vertex i connects to i±1..i±offsets_count (mod n)."""
    offs = np.arange(1, offsets_count + 1, dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), offs.shape[0])
    dst = (src + np.tile(offs, n)) % n
    return CSRGraph.from_edges(np.stack([src, dst], 1), n, symmetrize=True)


def erdos_renyi(n: int, num_edges: int, seed: int = 0) -> CSRGraph:
    """RandomG: G(n, m) by sampling m directed pairs then symmetrising."""
    rng = np.random.default_rng(seed)
    # oversample to survive self-loop/dup removal
    m = int(num_edges * 1.15) + 16
    src = rng.integers(0, n, m, dtype=np.int64)
    dst = rng.integers(0, n, m, dtype=np.int64)
    keep = src != dst
    edges = np.stack([src[keep], dst[keep]], 1)[:num_edges]
    return CSRGraph.from_edges(edges, n, symmetrize=True)


def barabasi_albert(n: int, m: int, seed: int = 0) -> CSRGraph:
    """BASF: preferential attachment, vectorised via the repeated-target trick
    (attach to a uniform sample of the current edge-endpoint multiset)."""
    rng = np.random.default_rng(seed)
    if n <= m:
        raise ValueError("n must exceed m")
    targets = list(range(m))
    repeated: list[int] = []
    src_all = np.empty((n - m) * m, dtype=np.int64)
    dst_all = np.empty((n - m) * m, dtype=np.int64)
    k = 0
    rep = np.array(targets, dtype=np.int64)
    for v in range(m, n):
        # choose m distinct-ish targets from the endpoint multiset
        pick = rep[rng.integers(0, rep.shape[0], m)]
        src_all[k : k + m] = v
        dst_all[k : k + m] = pick
        k += m
        rep = np.concatenate([rep, pick, np.full(m, v, dtype=np.int64)])
        if rep.shape[0] > 4_000_000:  # bound memory; subsample keeps proportions
            rep = rep[rng.integers(0, rep.shape[0], 2_000_000)]
    edges = np.stack([src_all, dst_all], 1)
    return CSRGraph.from_edges(edges, n, symmetrize=True)


def stochastic_block_model(
    sizes: list[int], p_in: float, p_out: float, seed: int = 0
) -> CSRGraph:
    """SBM with per-pair Binomial edge counts + uniform endpoint sampling."""
    rng = np.random.default_rng(seed)
    starts = np.zeros(len(sizes) + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    n = int(starts[-1])
    chunks = []
    B = len(sizes)
    for i in range(B):
        for j in range(i, B):
            ni, nj = sizes[i], sizes[j]
            pairs = ni * (ni - 1) // 2 if i == j else ni * nj
            p = p_in if i == j else p_out
            m = rng.binomial(pairs, p)
            if m == 0:
                continue
            s = rng.integers(starts[i], starts[i + 1], m, dtype=np.int64)
            d = rng.integers(starts[j], starts[j + 1], m, dtype=np.int64)
            chunks.append(np.stack([s, d], 1))
    edges = np.concatenate(chunks, 0) if chunks else np.zeros((0, 2), np.int64)
    return CSRGraph.from_edges(edges, n, symmetrize=True)


def rmat(
    scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
    c: float = 0.19, seed: int = 0,
) -> CSRGraph:
    """Graph500 Kronecker/RMAT generator (Kron29 analogue, scaled down)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a, b, c, d)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(m)
        thr = np.where(src_bit == 0, a / (a + b), c / max(1.0 - a - b, 1e-9))
        dst_bit = (r2 >= thr).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    edges = np.stack([src, dst], 1)
    return CSRGraph.from_edges(edges, n, symmetrize=True)
