"""GraSorw core: I/O-efficient second-order random walks (the paper's system)."""

from .buckets import (
    bucket_ids,
    skewed_block_assignment,
    split_into_buckets,
    traditional_block_assignment,
)
from .engine import (
    BiBlockEngine,
    InMemoryWalker,
    PlainBucketEngine,
    SOGWEngine,
    WalkResult,
    advance_pair,
)
from .generators import (
    barabasi_albert,
    circulant_graph,
    erdos_renyi,
    rmat,
    stochastic_block_model,
)
from .graph import BlockedGraph, CSRGraph, ResidentBlock, block_of
from .loader import BlockLoadingModel, LinearCostModel
from .partition import (
    greedy_locality_partition,
    partition_into_n_blocks,
    sequential_partition,
)
from .scheduler import (
    make_scheduler,
    standard_block_io_bound,
    triangular_block_io_bound,
    triangular_pairs,
)
from .stats import HBM_V5E, ICI_V5E, SSD, DevicePreset, IOStats
from .transition import (
    DeepWalk,
    Node2vec,
    WalkTask,
    deepwalk_task,
    prnv_task,
    rwnv_task,
)
from .walk import WALK_BYTES, WalkBatch, pack_walks, unpack_walks

__all__ = [
    "BiBlockEngine", "InMemoryWalker", "PlainBucketEngine", "SOGWEngine",
    "WalkResult", "advance_pair", "BlockedGraph", "CSRGraph", "ResidentBlock",
    "block_of", "BlockLoadingModel", "LinearCostModel",
    "greedy_locality_partition", "partition_into_n_blocks",
    "sequential_partition", "make_scheduler", "standard_block_io_bound",
    "triangular_block_io_bound", "triangular_pairs", "DevicePreset", "IOStats",
    "SSD", "HBM_V5E", "ICI_V5E", "DeepWalk", "Node2vec", "WalkTask",
    "deepwalk_task", "prnv_task", "rwnv_task", "WalkBatch", "WALK_BYTES",
    "pack_walks", "unpack_walks", "bucket_ids", "skewed_block_assignment",
    "split_into_buckets", "traditional_block_assignment", "barabasi_albert",
    "circulant_graph", "erdos_renyi", "rmat", "stochastic_block_model",
]
