"""GraSorw core: I/O-efficient second-order random walks (the paper's system).

Engine classes (:mod:`repro.engines`) and the storage layer (:mod:`repro.io`)
are re-exported lazily (PEP 562): they import this package's submodules, so
eager re-imports here would be circular.  ``from repro.core import
BiBlockEngine`` still works; so do ``import repro.engines`` and ``import
repro.io`` on a fresh interpreter.
"""

import importlib

from .buckets import (
    bucket_ids,
    skewed_block_assignment,
    split_into_buckets,
    traditional_block_assignment,
)
from .generators import (
    barabasi_albert,
    circulant_graph,
    erdos_renyi,
    rmat,
    stochastic_block_model,
)
from .graph import BlockedGraph, BlockView, CSRGraph, ResidentBlock, block_of
from .loader import BlockLoadingModel, LinearCostModel
from .partition import (
    greedy_locality_partition,
    partition_into_n_blocks,
    sequential_partition,
)
from .scheduler import (
    make_scheduler,
    standard_block_io_bound,
    triangular_block_io_bound,
    triangular_pairs,
)
from .stats import HBM_V5E, ICI_V5E, SSD, DevicePreset, IOStats
from .transition import (
    DeepWalk,
    Node2vec,
    WalkTask,
    deepwalk_task,
    prnv_task,
    rwnv_task,
)
from .walk import WALK_BYTES, WalkBatch, pack_walks, unpack_walks

#: lazily re-exported names -> providing module (avoids import cycles)
_LAZY = {
    "BiBlockEngine": "repro.engines",
    "EngineBase": "repro.engines",
    "InMemoryWalker": "repro.engines",
    "PlainBucketEngine": "repro.engines",
    "SOGWEngine": "repro.engines",
    "WalkResult": "repro.engines",
    "ResidentPair": "repro.engines",
    "advance_pair": "repro.engines",
    "pair_advance_impl": "repro.engines",
    "BlockStore": "repro.io",
    "BlockFileError": "repro.io",
    "DiskBlockedGraph": "repro.io",
    "write_block_file": "repro.io",
    "write_and_open": "repro.io",
    "DiskWalkPool": "repro.io",
    "MemoryWalkPool": "repro.io",
    "ShardedWalkPool": "repro.io",
    "WalkPool": "repro.io",
    "make_walk_pool": "repro.io",
}


def __getattr__(name):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))


__all__ = [
    "BiBlockEngine", "EngineBase", "InMemoryWalker", "PlainBucketEngine",
    "SOGWEngine", "BlockStore", "DiskWalkPool", "MemoryWalkPool",
    "ShardedWalkPool", "WalkPool",
    "make_walk_pool", "BlockFileError", "DiskBlockedGraph", "write_block_file",
    "write_and_open",
    "WalkResult", "advance_pair", "BlockedGraph", "CSRGraph", "ResidentBlock",
    "block_of", "BlockLoadingModel", "LinearCostModel",
    "greedy_locality_partition", "partition_into_n_blocks",
    "sequential_partition", "make_scheduler", "standard_block_io_bound",
    "triangular_block_io_bound", "triangular_pairs", "DevicePreset", "IOStats",
    "SSD", "HBM_V5E", "ICI_V5E", "DeepWalk", "Node2vec", "WalkTask",
    "deepwalk_task", "prnv_task", "rwnv_task", "WalkBatch", "WALK_BYTES",
    "pack_walks", "unpack_walks", "bucket_ids", "skewed_block_assignment",
    "split_into_buckets", "traditional_block_assignment", "barabasi_albert",
    "circulant_graph", "erdos_renyi", "rmat", "stochastic_block_model",
]
