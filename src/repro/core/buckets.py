"""Skewed walk storage + bucket-based in-memory walk management (§4.3).

*Skewed walk storage* (§4.3.1): a walk ``w_u^v`` persists with block
``min(B(u), B(v))`` — this is what makes the triangular schedule complete
(every stored walk's pair is visited in the time slot of its min block).

*Bucketing* (§4.3.2, Eq. 4 / Alg. 1 lines 4-10): within the time slot of
current block ``b``, a walk goes to bucket ``B(v)`` if ``B(u) == b`` else
``B(u)``; with the skewed invariant the bucket id is always ``> b``.

Both are vectorised: bucketing is one ``where`` + a stable counting sort, the
direct analogue of the paper's per-thread bucket buffers merged lock-free.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .graph import block_of
from .walk import WalkBatch

__all__ = [
    "skewed_block_assignment",
    "traditional_block_assignment",
    "bucket_ids",
    "push_by_block_assignment",
    "split_into_buckets",
]


def skewed_block_assignment(block_starts: np.ndarray, batch: WalkBatch) -> np.ndarray:
    """Block a walk persists with under skewed storage: min(B(u), B(v))."""
    bp = block_of(block_starts, batch.prev)
    bc = block_of(block_starts, batch.cur)
    return np.minimum(bp, bc)


def traditional_block_assignment(block_starts: np.ndarray, batch: WalkBatch) -> np.ndarray:
    """Traditional storage (baselines): a walk lives with B(cur)."""
    return block_of(block_starts, batch.cur)


def push_by_block_assignment(pool, block_starts, order: int, batch: WalkBatch, wid) -> None:
    """Persist ``batch`` through ``pool`` under the walk-storage rule —
    skewed ``min(B(u), B(v))`` for second order, traditional ``B(cur)``
    for first (§7.8).  The single association every tier persists with:
    the bi-block engine and the distributed driver both call this, so the
    keying cannot silently diverge between them."""
    if len(batch) == 0:
        return
    if order == 1:
        assoc = traditional_block_assignment(block_starts, batch)
    else:
        assoc = skewed_block_assignment(block_starts, batch)
    for b in np.unique(assoc):
        m = assoc == b
        pool.push(int(b), batch.select(m), wid[m])


def bucket_ids(block_starts: np.ndarray, batch: WalkBatch, current_block: int) -> np.ndarray:
    """Eq. 4: bucket = B(v) if B(u) == b else B(u)."""
    bp = block_of(block_starts, batch.prev)
    bc = block_of(block_starts, batch.cur)
    return np.where(bp == current_block, bc, bp)


def split_into_buckets(
    block_starts: np.ndarray,
    batch: WalkBatch,
    current_block: int,
    wid: Optional[np.ndarray] = None,
) -> Dict[int, Tuple[WalkBatch, np.ndarray]]:
    """Group current walks into buckets (stable counting sort by bucket id).

    Returns wid-aligned ``bucket_id -> (WalkBatch, wid)`` pairs so callers
    never re-sort to realign walk ids.  When ``wid`` is omitted, positional
    ids ``arange(len(batch))`` are used.
    """
    if len(batch) == 0:
        return {}
    if wid is None:
        wid = np.arange(len(batch), dtype=np.int64)
    ids = bucket_ids(block_starts, batch, current_block)
    order = np.argsort(ids, kind="stable")
    ids_sorted = ids[order]
    batch = batch.select(order)
    wid_sorted = wid[order]
    # segment boundaries
    uniq, starts = np.unique(ids_sorted, return_index=True)
    out: Dict[int, Tuple[WalkBatch, np.ndarray]] = {}
    bounds = list(starts) + [len(batch)]
    for k, b_id in enumerate(uniq):
        seg = slice(bounds[k], bounds[k + 1])
        out[int(b_id)] = (batch.select(seg), wid_sorted[seg])
    return out
