"""Samplers: alias tables (first-order draws) and the second-order
rejection sampler used by the Node2vec transition (KnightKing-style).

Everything exists twice:
  * host numpy builders (graph preprocessing — alias tables per block), and
  * pure-jnp batched step functions (the oracle the Pallas kernels are
    validated against, and the implementation the engine jits on CPU).

Why rejection sampling?  A second-order step needs `p(z|u,v) ∝ a'_{vz}`
(Eq. 1) whose normaliser depends on the *pair* (u, v) — materialising the
edge-edge distribution is O(sum_v deg(v)^2) memory (the reason in-memory
systems give up on big graphs).  Instead: propose `z ∝ a_vz` from v's alias
table, accept with `a'_{vz} / (M · a_vz)` where `M = max(1, 1/p, 1/q)`; the
accept test only needs `h_uz ∈ {0,1,2}`, i.e. a membership probe `z ∈ N(u)`
— a binary search over u's sorted adjacency.  All memory touched lives in
the resident block pair, which is the property the bi-block engine exploits.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "build_alias",
    "build_alias_rows",
    "alias_draw_np",
    "alias_draw",
    "searchsorted_rows",
    "membership",
    "node2vec_accept_prob",
]


# ---------------------------------------------------------------------------
# Alias tables (Walker's method) — host-side builders
# ---------------------------------------------------------------------------

def build_alias(probs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Classic O(n) alias construction for one distribution.

    Returns (J, q): draw slot k uniformly, draw r ~ U[0,1); result is k if
    r < q[k] else J[k].
    """
    probs = np.asarray(probs, dtype=np.float64)
    n = probs.shape[0]
    if n == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.float32)
    s = probs.sum()
    if s <= 0:
        probs = np.full(n, 1.0 / n)
    else:
        probs = probs / s
    q = probs * n
    J = np.arange(n, dtype=np.int32)
    small = [i for i in range(n) if q[i] < 1.0]
    large = [i for i in range(n) if q[i] >= 1.0]
    while small and large:
        s_i = small.pop()
        l_i = large.pop()
        J[s_i] = l_i
        q[l_i] = q[l_i] - (1.0 - q[s_i])
        if q[l_i] < 1.0:
            small.append(l_i)
        else:
            large.append(l_i)
    return J.astype(np.int32), np.minimum(q, 1.0).astype(np.float32)


def build_alias_rows(
    indptr: np.ndarray, nverts: int, pad_len: int, weights: Optional[np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-vertex alias tables over a block's CSR rows, stored edge-aligned
    and padded to ``pad_len`` (so tables stack uniformly across blocks).

    ``J`` holds *local* (within-row) alias indices so a row's table is
    position-independent — the engine adds the row offset at draw time.
    """
    pad_len = max(pad_len, 1)
    J = np.zeros(pad_len, dtype=np.int32)
    q = np.ones(pad_len, dtype=np.float32)
    for v in range(nverts):
        s, e = int(indptr[v]), int(indptr[v + 1])
        if e <= s:
            continue
        w = weights[s:e] if weights is not None else np.ones(e - s)
        Jr, qr = build_alias(w)
        J[s:e] = Jr
        q[s:e] = qr
    return J, q


def alias_draw_np(
    J: np.ndarray, q: np.ndarray, row_start: np.ndarray, row_deg: np.ndarray,
    u1: np.ndarray, u2: np.ndarray,
) -> np.ndarray:
    """Vectorised alias draw (numpy). Returns *local* neighbor slot per row."""
    k = np.minimum((u1 * row_deg).astype(np.int64), row_deg - 1)
    idx = row_start + k
    take_alias = u2 >= q[idx]
    return np.where(take_alias, J[idx].astype(np.int64), k)


@partial(jax.jit, static_argnames=())
def alias_draw(J, q, row_start, row_deg, u1, u2):
    """jnp twin of :func:`alias_draw_np` (the kernel oracle)."""
    k = jnp.minimum((u1 * row_deg).astype(jnp.int32), row_deg - 1)
    k = jnp.maximum(k, 0)
    idx = row_start + k
    take_alias = u2 >= q[idx]
    return jnp.where(take_alias, J[idx], k)


# ---------------------------------------------------------------------------
# Membership probe: z in N(u) via binary search over sorted adjacency rows
# ---------------------------------------------------------------------------

def searchsorted_rows(indices, lo, hi, z, *, n_iters: int):
    """Batched binary search of ``z`` within ``indices[lo:hi]`` (sorted rows).

    Branch-free: fixed ``n_iters = ceil(log2(max_row_len))+1`` halvings, which
    is what the Pallas kernel runs on the VPU.  Returns True iff found.
    """
    lo0 = lo.astype(jnp.int32)
    hi0 = hi.astype(jnp.int32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        val = indices[jnp.clip(mid, 0, indices.shape[0] - 1)]
        valid = lo_ < hi_
        go_right = valid & (val < z)
        lo_ = jnp.where(go_right, mid + 1, lo_)
        hi_ = jnp.where(valid & ~go_right, mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    pos = jnp.clip(lo_f, 0, indices.shape[0] - 1)
    return (lo_f < hi0) & (indices[pos] == z)


def membership(indices, lo, hi, z, *, n_iters: int):
    """True iff z appears in the sorted slice indices[lo:hi]."""
    return searchsorted_rows(indices, lo, hi, z, n_iters=n_iters)


# ---------------------------------------------------------------------------
# Node2vec acceptance
# ---------------------------------------------------------------------------

def node2vec_accept_prob(z, u, is_neighbor_of_u, p: float, q: float):
    """`a'_vz / (M a_vz)` with M = max(1, 1/p, 1/q)  (Eq. 1, unweighted bias).

    h_uz = 0 (z == u)        -> 1/p
    h_uz = 1 (z in N(u))     -> 1
    h_uz = 2 (otherwise)     -> 1/q
    """
    M = max(1.0, 1.0 / p, 1.0 / q)
    bias = jnp.where(
        z == u, 1.0 / p, jnp.where(is_neighbor_of_u, 1.0, 1.0 / q)
    )
    return bias / M
