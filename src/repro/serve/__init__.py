"""Query serving: point random-walk queries over the disk-based engine.

The batch tiers answer the paper's offline workloads (RWNV/PRNV over every
vertex, §7.1); this package is the online front end the ROADMAP's
production-serving arc calls for.  It turns a stream of ``(source,
config)`` point queries into admission batches
(:mod:`~repro.serve.admission`) that ride the stock triangular bi-block
sweep (§4.2) through the ``initial_walks`` /shared-``BlockStore`` seams of
:class:`~repro.engines.base.EngineBase`, pins the query-traffic hot set of
blocks in memory (:mod:`~repro.serve.policy`), and materializes per-query
PPR / neighbor-multiset answers with submit→answer latency
(:mod:`~repro.serve.query`, :mod:`~repro.serve.server`).

Everything is deterministic: the counter-based RNG makes served walks bit
identical to the equivalent direct batch run, and pinning changes only
what is *charged*, never what executes — both properties are asserted by
the ``query_serving`` bench.
"""

from .admission import AdmissionQueue
from .policy import HotSetPolicy
from .query import QueryAnswer, QueryConfig, WalkQuery
from .server import WalkQueryServer

__all__ = [
    "AdmissionQueue",
    "HotSetPolicy",
    "QueryAnswer",
    "QueryConfig",
    "WalkQuery",
    "WalkQueryServer",
]
