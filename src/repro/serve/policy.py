"""Hot-set policy: which blocks serve from memory, which degrade to disk.

Serving traffic is skewed — most queries start near a few hub vertices
(the power-law regime of §7.6's graph families), so a few graph blocks
absorb most of the sweep's block loads.  The :class:`HotSetPolicy` keeps a
query-arrival histogram over blocks (each submitted query's source block
counts one arrival) and names the current top-``max_pinned`` blocks as the
*hot set*.  The server pins them into the
:class:`~repro.io.BlockStore` — pinned blocks are held resident outside
the LRU, loaded (and charged) once, and served chargeless thereafter;
eviction governs only the cold tail.  That is ThunderRW's in-memory
serving regime on the hot set with the paper's disk economics on the cold
tail, and the savings are deterministic gauges
(``IOStats.pinned_block_hits`` / ``pinned_bytes_saved``).

The decision is program-order pure: the histogram depends only on the
submission sequence, ties break toward the lower block id, and blocks
need ``min_arrivals`` before qualifying (a single stray query should not
pin a megablock).
"""

from __future__ import annotations

import numpy as np

__all__ = ["HotSetPolicy"]


class HotSetPolicy:
    """Top-``max_pinned`` blocks of the query-arrival histogram.

    ``max_pinned=0`` disables pinning entirely — the pure-LRU reference
    the ``query_serving`` bench compares against.
    """

    def __init__(self, num_blocks: int, *, max_pinned: int = 2, min_arrivals: int = 1):
        if max_pinned < 0:
            raise ValueError("max_pinned must be >= 0")
        self.num_blocks = num_blocks
        self.max_pinned = max_pinned
        self.min_arrivals = max(int(min_arrivals), 1)
        self.arrivals = np.zeros(num_blocks, np.int64)

    def observe(self, block: int, n: int = 1) -> None:
        """Record ``n`` query arrivals whose source lives in ``block``."""
        self.arrivals[int(block)] += int(n)

    def hot_set(self) -> np.ndarray:
        """Current hot set: up to ``max_pinned`` block ids, by descending
        arrivals (ties toward the lower id), qualifying at
        ``min_arrivals``.  Sorted ascending for stable pinning calls."""
        if self.max_pinned == 0:
            return np.zeros(0, np.int64)
        order = np.lexsort((np.arange(self.num_blocks), -self.arrivals))
        top = order[: self.max_pinned]
        top = top[self.arrivals[top] >= self.min_arrivals]
        return np.sort(top).astype(np.int64)
