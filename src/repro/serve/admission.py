"""Admission batching: the throughput half of the latency/throughput dial.

One block load amortized over thousands of walks is the paper's central
economy (§4.2, §6.1).  A point query alone cannot buy it — ``samples`` of
32 walks would pay a whole triangular sweep.  The :class:`AdmissionQueue`
restores the economy by *batching admissions*: pending queries group by
:class:`~repro.serve.query.QueryConfig` (one engine run serves one
config), and :meth:`pop_batch` admits up to ``max_batch`` of the oldest
group at once, FIFO within the group.  Every query in the admitted batch
rides the same sweep, so each block load is shared ``batch x samples``
ways — and every query in the batch answers at the same time, which is
exactly the tradeoff: larger admission batches amortize better (higher
throughput per I/O) but hold early arrivals longer (higher p50 latency).
``max_batch`` is the dial; the ``query_serving`` bench reports the
percentile consequences.

Order is deterministic: groups are served oldest-head-first (smallest
pending query id), queries within a group in submission order — so the
walk-id layout of every admitted batch, and therefore (with the
counter-based RNG) every trajectory, is a pure function of the submission
sequence.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, List, Optional, Tuple

from .query import QueryConfig, WalkQuery

__all__ = ["AdmissionQueue"]


class AdmissionQueue:
    """Pending point queries, grouped by config, admitted in FIFO batches."""

    def __init__(self, max_batch: int = 1024):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self._groups: "OrderedDict[QueryConfig, Deque[WalkQuery]]" = OrderedDict()

    def __len__(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def submit(self, query: WalkQuery) -> None:
        self._groups.setdefault(query.config, deque()).append(query)

    def pop_batch(self) -> Optional[Tuple[QueryConfig, List[WalkQuery]]]:
        """Admit up to ``max_batch`` queries of one config — the group whose
        head query has waited longest (smallest qid) — or ``None`` when
        nothing is pending."""
        best = None
        for cfg, grp in self._groups.items():
            if grp and (best is None or grp[0].qid < self._groups[best][0].qid):
                best = cfg
        if best is None:
            return None
        grp = self._groups[best]
        batch = [grp.popleft() for _ in range(min(self.max_batch, len(grp)))]
        if not grp:
            del self._groups[best]
        return best, batch
