"""The walk-query server: point queries riding the triangular sweep.

:class:`WalkQueryServer` is the front end the ROADMAP's serving item
describes.  Life of a query:

1. **submit** — ``submit(source, config)`` stamps the arrival clock,
   records the source's block in the :class:`~repro.serve.policy
   .HotSetPolicy` histogram, and parks the query in the
   :class:`~repro.serve.admission.AdmissionQueue`.
2. **admit** — ``flush()`` pops admission batches (one config per batch,
   up to ``max_batch`` queries).  Each batch becomes *one* engine run: the
   queries' sources repeat ``samples`` times into a single walk array
   (query ``k`` owns the contiguous walk-id range ``[k·samples,
   (k+1)·samples)``), injected through the ``initial_walks`` seam of
   :class:`~repro.engines.base.EngineBase`.
3. **sweep** — the run is a stock bi-block triangular sweep (§4.2) over
   the *shared* :class:`~repro.io.BlockStore` and ``IOStats`` the server
   owns, with the policy's current hot set pinned: hot blocks load once
   and serve chargeless from memory, the cold tail keeps the paper's disk
   economics.  Walks persist with the skewed ``min(B(u), B(v))`` rule via
   the same ``core.buckets.push_by_block_assignment`` every tier uses, so
   thousands of concurrent queries amortize each block load — §4.2's
   bucket economics as a latency story.
4. **answer** — the engine's ``on_retire`` hook hands every terminating
   walk's ``(walk id, endpoint)`` back; walk ids fold to query ids and the
   per-query endpoint multisets materialize as
   :class:`~repro.serve.query.QueryAnswer`\\ s (PPR estimate / neighbor
   multiset).  ``t_answer`` stamps the clock; submit→answer is the
   per-query latency, summarized by :meth:`latency_summary` percentiles.

Determinism: batch ``k`` (0-based, across the server's lifetime) runs with
task seed ``seed + k``, and walk trajectories are pure functions of
``(seed, walk id)`` (counter-based RNG) — so a served batch is *bit
identical* to the equivalent direct batch run (same engine class, same
task seed, ``initial_walks`` = the same concatenated sources).  Pinning
never changes what executes, only what is charged.  The ``query_serving``
bench asserts both: served CRC == direct CRC, and hot-set ``block_load``
charges strictly below pure LRU on a skewed mix.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.graph import block_of
from repro.core.stats import SSD, DevicePreset, IOStats
from repro.engines.biblock import BiBlockEngine
from repro.io import BlockStore

from .admission import AdmissionQueue
from .policy import HotSetPolicy
from .query import QueryAnswer, QueryConfig, WalkQuery

__all__ = ["WalkQueryServer"]

DEFAULT_CONFIG = QueryConfig()


class WalkQueryServer:
    """Admission-batched point-query serving over one blocked graph.

    ``engine_kw`` flows to every batch's engine run (``pool``,
    ``loading``, ``async_pipeline``, ``advance_impl``, ...); the block
    store and stats are server-owned and shared across runs, so hot-set
    pinning savings compound over the server's lifetime.
    ``hot_blocks=0`` disables pinning (the pure-LRU reference).
    """

    def __init__(
        self,
        bg,
        *,
        max_batch: int = 1024,
        hot_blocks: int = 2,
        hot_min_arrivals: int = 1,
        block_cache_blocks: int = 4,
        prefetch: bool = True,
        preset: DevicePreset = SSD,
        seed: int = 0,
        engine_cls=BiBlockEngine,
        **engine_kw,
    ):
        self.bg = bg
        self.seed = seed
        self.engine_cls = engine_cls
        self.engine_kw = engine_kw
        self.stats = IOStats(preset)
        self.blocks = BlockStore(
            bg,
            self.stats,
            enable_prefetch=prefetch,
            capacity=max(block_cache_blocks, 2),
        )
        self.admission = AdmissionQueue(max_batch)
        self.policy = HotSetPolicy(
            bg.num_blocks, max_pinned=hot_blocks, min_arrivals=hot_min_arrivals
        )
        self._queries: Dict[int, WalkQuery] = {}
        self._answers: Dict[int, QueryAnswer] = {}
        self._next_qid = 0
        self.batches_served = 0
        self._closed = False

    # -- the submit side -------------------------------------------------------
    def submit(self, source: int, config: QueryConfig = DEFAULT_CONFIG) -> int:
        """Enqueue one point query; returns its query id."""
        source = int(source)
        if not (0 <= source < self.bg.num_vertices):
            raise ValueError(f"query source {source} outside [0, {self.bg.num_vertices})")
        qid = self._next_qid
        self._next_qid += 1
        query = WalkQuery(qid, source, config, t_submit=time.perf_counter())
        self._queries[qid] = query
        self.policy.observe(int(block_of(self.bg.block_starts, np.array([source]))[0]))
        self.admission.submit(query)
        return qid

    def pending(self) -> int:
        return len(self.admission)

    # -- the serve side --------------------------------------------------------
    def batch_seed(self, k: int) -> int:
        """Task seed of the server's ``k``-th admitted batch — the seed a
        direct batch run must use to reproduce its walks bit-for-bit."""
        return self.seed + k

    def flush(self) -> List[QueryAnswer]:
        """Serve every pending query; returns their answers in qid order."""
        served: List[QueryAnswer] = []
        while True:
            popped = self.admission.pop_batch()
            if popped is None:
                return served
            served.extend(self._serve_batch(*popped))

    def _serve_batch(self, config: QueryConfig, queries: List[WalkQuery]) -> List[QueryAnswer]:
        # pin the policy's current hot set before the sweep touches blocks
        self.blocks.set_pinned(self.policy.hot_set())
        samples = config.samples
        sources = np.repeat(np.array([q.source for q in queries], np.int64), samples)
        # every terminating walk reports (wid, endpoint) exactly once
        wid_parts: List[np.ndarray] = []
        end_parts: List[np.ndarray] = []

        def collect(wid: np.ndarray, ends: np.ndarray) -> None:
            wid_parts.append(np.asarray(wid, np.int64).copy())
            end_parts.append(np.asarray(ends, np.int64).copy())

        task = config.task(self.batch_seed(self.batches_served))
        engine = self.engine_cls(
            self.bg,
            task,
            stats=self.stats,
            block_store=self.blocks,
            initial_walks=sources,
            on_retire=collect,
            **self.engine_kw,
        )
        engine.run()
        self.batches_served += 1
        wid = np.concatenate(wid_parts) if wid_parts else np.zeros(0, np.int64)
        ends = np.concatenate(end_parts) if end_parts else np.zeros(0, np.int64)
        qidx = wid // samples  # contiguous per-query walk-id ranges
        t_answer = time.perf_counter()
        answers = []
        for k, query in enumerate(queries):
            verts, counts = np.unique(ends[qidx == k], return_counts=True)
            query.t_answer = t_answer
            ans = QueryAnswer(
                qid=query.qid,
                source=query.source,
                num_walks=samples,
                vertices=verts.astype(np.int64),
                counts=counts.astype(np.int64),
                latency=query.latency,
            )
            self._answers[query.qid] = ans
            answers.append(ans)
        return answers

    # -- read-outs -------------------------------------------------------------
    def answer(self, qid: int) -> Optional[QueryAnswer]:
        return self._answers.get(qid)

    def latencies(self) -> np.ndarray:
        """Submit→answer seconds of every answered query, in qid order."""
        return np.array(
            [q.latency for q in self._queries.values() if q.t_answer is not None]
        )

    def latency_summary(self) -> Dict[str, float]:
        """p50/p95/p99 per-query latency (seconds) plus the answered count."""
        lat = self.latencies()
        if lat.size == 0:
            return {"answered": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "answered": int(lat.size),
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
        }

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.blocks.close()

    def __enter__(self) -> "WalkQueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
