"""Point-query vocabulary for the walk-serving front end.

The batch system answers "run W walks from *every* vertex" (RWNV, §7.1);
production traffic is millions of users asking "run a few walks from *my*
vertex" — personalized PageRank (the PRNV workload of Wu et al., §7.1) or
node2vec neighborhood samples for one item.  A :class:`WalkQuery` is one
such request: a source vertex plus the :class:`QueryConfig` describing its
walk population (Node2vec ``p``/``q`` of Eq. 1, max length, restart decay,
and ``samples`` — how many walks estimate this one answer).

Queries sharing a :class:`QueryConfig` can ride one engine run: the server
concatenates their sources into a single walk batch (every walk keeps a
contiguous walk-id range per query), so the triangular bi-block sweep
(§4.2) amortizes each block load across *all* concurrent queries — the
paper's bucket economics turned into a latency story.  A
:class:`QueryAnswer` is materialized from the walk endpoints the engine
retires for that query's walk ids: normalized, they are the Monte-Carlo
PPR estimate (walk-with-restart, §7.1); raw, they are the sampled
neighbor multiset.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.transition import Node2vec, WalkTask

__all__ = ["QueryConfig", "WalkQuery", "QueryAnswer"]


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    """Walk population of one point query.

    Queries with equal configs are admission-batched into one engine run
    (the config is the batching key), so keep the config space small in a
    serving deployment — a handful of products, not per-user knobs.
    """

    p: float = 1.0  # Node2vec return parameter (Eq. 1)
    q: float = 1.0  # Node2vec in-out parameter (Eq. 1)
    length: int = 20  # max hops per walk
    decay: float = 0.85  # continue probability per step (1 - restart prob)
    samples: int = 32  # walks estimating this query's answer

    def task(self, seed: int) -> WalkTask:
        """The :class:`WalkTask` an admitted batch of these queries runs
        as.  Walk sources are injected by the server (``initial_walks``
        engine seam), so the task only carries the shared model/termination
        settings — and the batch seed, which together with a walk's id
        fully determines its trajectory (counter-based RNG)."""
        return WalkTask(
            Node2vec(p=self.p, q=self.q),
            length=self.length,
            decay=self.decay,
            seed=seed,
        )


@dataclasses.dataclass
class WalkQuery:
    """One submitted query: identity, source, config, and its clock times
    (``t_submit`` at admission, ``t_answer`` when the answer materialized —
    the difference is the per-query serving latency)."""

    qid: int
    source: int
    config: QueryConfig
    t_submit: float
    t_answer: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        if self.t_answer is None:
            return None
        return self.t_answer - self.t_submit


@dataclasses.dataclass
class QueryAnswer:
    """Materialized answer: the endpoint multiset of one query's walks.

    ``vertices``/``counts`` are the unique termination vertices and their
    visit counts — sparse, because a query's ``samples`` walks touch far
    fewer vertices than the graph holds.  Both read-outs the ROADMAP names
    come from this one multiset: :meth:`ppr` (normalized counts — the
    Monte-Carlo walk-with-restart PPR estimate) and
    :meth:`neighbor_multiset` (raw counts — node2vec neighborhood samples).
    """

    qid: int
    source: int
    num_walks: int
    vertices: np.ndarray  # unique endpoint vertex ids, sorted
    counts: np.ndarray  # visits at termination, aligned with ``vertices``
    latency: float  # submit -> answer seconds (wall clock)

    def ppr(self) -> Tuple[np.ndarray, np.ndarray]:
        """Sparse PPR estimate: ``(vertices, probabilities)``."""
        tot = max(int(self.counts.sum()), 1)
        return self.vertices, self.counts / tot

    def top(self, k: int = 10) -> List[Tuple[int, float]]:
        """The ``k`` highest-probability vertices (ties break low-id)."""
        verts, probs = self.ppr()
        order = np.lexsort((verts, -probs))[:k]
        return [(int(verts[i]), float(probs[i])) for i in order]

    def neighbor_multiset(self) -> Dict[int, int]:
        """Endpoint multiset as ``vertex -> count``."""
        return {int(v): int(c) for v, c in zip(self.vertices, self.counts)}

    def dense_counts(self, num_vertices: int) -> np.ndarray:
        """Dense ``[V]`` endpoint histogram (CRC checks, oracle compares)."""
        out = np.zeros(num_vertices, np.int64)
        out[self.vertices] = self.counts
        return out
