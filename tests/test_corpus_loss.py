"""Walk corpus -> LM batch pipeline + loss masking + optimizer."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data import WalkCorpus, skipgram_pairs
from repro.optim import OptConfig, adamw_init, adamw_update, lr_schedule
from repro.train.loss import IGNORE, lm_loss


def _corpus(n=40, L=16, V=100, seed=0):
    rng = np.random.default_rng(seed)
    walks = rng.integers(0, V, (n, L + 1)).astype(np.int32)
    walks[5, 9:] = -1  # one early-terminated walk
    return WalkCorpus.from_walks(walks, V)


def test_batch_packing_shapes_and_shift():
    corpus = _corpus()
    it = corpus.batches(4, 12, epochs=1, seed=1)
    b = next(it)
    assert b["tokens"].shape == (4, 12)
    assert b["labels"].shape == (4, 12)
    # labels are the next-token shift within each row
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    flat_t = b["tokens"].reshape(-1)
    # vertex tokens are offset by BOS
    assert flat_t.max() < corpus.vocab_size
    assert (flat_t == 0).any(), "BOS separators present"


def test_cursor_resume_determinism():
    corpus = _corpus()
    ref = list(corpus.batches(2, 10, epochs=1, seed=3))
    # replay from the cursor of batch k
    k = 2
    resumed = list(
        corpus.batches(2, 10, cursor=ref[k - 1]["cursor"], epochs=1, seed=3)
    )
    # Note: resuming re-seeds the same permutation (seed fixed), so batch k
    # onward must match except buffered remainder; compare walk coverage
    np.testing.assert_array_equal(ref[k]["tokens"], resumed[0]["tokens"])


def test_skipgram_pairs_within_window():
    corpus = _corpus()
    c, x = skipgram_pairs(corpus.walks, window=3, max_pairs=500, seed=0)
    assert c.shape == x.shape and c.shape[0] <= 500
    assert (c >= 0).all() and (x >= 0).all()


def test_lm_loss_masking():
    cfg = reduced_config("qwen1.5-0.5b")
    B, S, V = 2, 6, cfg.vocab_padded
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((B, S, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    labels = labels.at[0, :3].set(IGNORE)
    loss, n = lm_loss(logits, labels, cfg)
    assert int(n) == B * S - 3
    assert np.isfinite(float(loss))
    # perfect logits -> ~0 loss
    perfect = jnp.full((B, S, V), -30.0)
    perfect = perfect.at[
        jnp.arange(B)[:, None], jnp.arange(S)[None, :], jnp.abs(labels)
    ].set(30.0)
    loss_p, _ = lm_loss(perfect, labels, cfg)
    assert float(loss_p) < 1e-3


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.2, warmup_steps=1, total_steps=200, weight_decay=0.0)
    f = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(f)(params)
        params, opt, m = adamw_update(g, opt, params, cfg)
    assert float(f(params)) < 1e-3
    assert float(m["grad_norm"]) < 1.0


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6
