"""Per-arch smoke tests (reduced configs) + decode/forward equivalence.

The equivalence test is the strongest model-correctness check we can run on
CPU: teacher-forced forward logits at position t must equal prefill(0..t-1)
followed by one decode step — across every cache type (GQA ring/linear KV,
MLA latent with absorbed decode, SSD state, RG-LRU state, enc-dec).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, reduced_config
from repro.models import (
    model_caches,
    model_decode,
    model_forward,
    model_init,
    model_prefill,
)
from repro.optim import OptConfig, adamw_init
from repro.train import make_train_step

B, S = 2, 24


def _batch(cfg, rng, seq=S):
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, seq)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)).astype(np.int32)),
    }
    if cfg.frontend == "vision":
        batch["prefix"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix, cfg.d_model)).astype(np.float32)
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, seq, cfg.d_model)).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(0)
    params = model_init(jax.random.PRNGKey(0), cfg)
    logits, aux = model_forward(params, _batch(cfg, rng), cfg)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(1)
    params = model_init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, rng)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=5e-3, warmup_steps=1,
                                                  total_steps=50)))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    if cfg.skip_decode:
        pytest.skip("encoder-only")
    rng = np.random.default_rng(2)
    params = model_init(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg, rng)
    toks = batch["tokens"]

    # teacher-forced logits at the last position
    full_logits, _ = model_forward(params, batch, cfg)
    want = np.asarray(full_logits[:, -1], np.float32)

    # prefill on tokens[:-1], then decode tokens[-1]
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    pre.pop("labels")
    _, caches = model_prefill(params, pre, cfg)
    # pad caches to a fixed decode buffer (prefix positions included)
    prefix_len = cfg.num_prefix if cfg.frontend == "vision" else 0
    target = model_caches(cfg, B, S + prefix_len + 4, enc_len=S)

    def pad_to(got, tgt):
        if got.shape == tgt.shape:
            return got
        pads = [(0, t - g) for g, t in zip(got.shape, tgt.shape)]
        return jnp.pad(got, pads)

    caches = jax.tree.map(pad_to, caches, target)
    prefix = cfg.num_prefix if cfg.frontend == "vision" else 0
    cache_len = jnp.int32(S - 1 + prefix)
    logits, _ = model_decode(params, toks[:, -1:], caches, cache_len, cfg)
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_vlm_prefix_changes_logits():
    cfg = reduced_config("internvl2-1b")
    rng = np.random.default_rng(3)
    params = model_init(jax.random.PRNGKey(3), cfg)
    b1 = _batch(cfg, rng)
    b2 = dict(b1, prefix=jnp.zeros_like(b1["prefix"]))
    l1, _ = model_forward(params, b1, cfg)
    l2, _ = model_forward(params, b2, cfg)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_moe_aux_loss_nonzero():
    cfg = reduced_config("mixtral-8x22b")
    rng = np.random.default_rng(4)
    params = model_init(jax.random.PRNGKey(4), cfg)
    _, aux = model_forward(params, _batch(cfg, rng), cfg)
    assert float(aux) > 0.0


def test_long_context_flags():
    from repro.configs import get_config, shape_applicable

    assert shape_applicable(get_config("mamba2-2.7b"), "long_500k")
    assert shape_applicable(get_config("recurrentgemma-2b"), "long_500k")
    for dense in ("yi-34b", "qwen1.5-0.5b", "whisper-tiny", "mixtral-8x22b"):
        assert not shape_applicable(get_config(dense), "long_500k")
