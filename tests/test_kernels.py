"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/param sweeps."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from repro.testing import given, settings, st

from repro.core import erdos_renyi, partition_into_n_blocks
from repro.kernels import (
    alias_step,
    bucket_hist_kernel,
    bucket_hist_ref,
    node2vec_step,
    node2vec_step_kernel,
    node2vec_step_ref,
)


def _pair_args(n_verts=500, n_edges=3500, nb=4, b0=0, b1=2, weighted=False, seed=1):
    g = erdos_renyi(n_verts, n_edges, seed=seed)
    if weighted:
        rng = np.random.default_rng(seed)
        from repro.core import CSRGraph

        g = CSRGraph(g.indptr, g.indices,
                     (rng.random(g.num_edges) + 0.1).astype(np.float32))
    bg = partition_into_n_blocks(g, nb)
    if weighted:
        bg._build_alias = True
    a, b = bg.materialize_block(b0), bg.materialize_block(b1)
    pair_start = jnp.array([a.start, b.start], jnp.int32)
    pair_nverts = jnp.array([a.nverts, b.nverts], jnp.int32)
    indptr = jnp.stack([jnp.asarray(a.indptr), jnp.asarray(b.indptr)])
    indices = jnp.stack([jnp.asarray(a.indices), jnp.asarray(b.indices)])
    if weighted and a.alias_j is not None:
        aj = jnp.stack([jnp.asarray(a.alias_j), jnp.asarray(b.alias_j)])
        aq = jnp.stack([jnp.asarray(a.alias_q), jnp.asarray(b.alias_q)])
    else:
        aj = jnp.zeros_like(indices)
        aq = jnp.ones(indices.shape, jnp.float32)
    return bg, (pair_start, pair_nverts, indptr, indices, aj, aq)


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (4.0, 0.25), (0.25, 4.0)])
@pytest.mark.parametrize("n_walks", [256, 1024])
def test_node2vec_kernel_matches_ref(p, q, n_walks):
    bg, pair = _pair_args()
    rng = np.random.default_rng(0)
    s0, e0 = bg.block_starts[0], bg.block_starts[1]
    cur = jnp.asarray(rng.integers(s0, e0, n_walks).astype(np.int32))
    s1, e1 = bg.block_starts[2], bg.block_starts[3]
    prev = jnp.asarray(rng.integers(s1, e1, n_walks).astype(np.int32))
    hop = jnp.asarray(rng.integers(0, 6, n_walks).astype(np.int32))
    active = jnp.asarray(rng.random(n_walks) < 0.9)
    unif = jax.random.uniform(jax.random.PRNGKey(7), (n_walks, 4, 3))
    kw = dict(p=p, q=q, k_max=4, n_iters=16)
    zk, mk = node2vec_step_kernel(*pair, prev, cur, hop, active, unif,
                                  interpret=True, walk_tile=256, **kw)
    zr, mr = node2vec_step_ref(*pair, prev, cur, hop, active, unif, **kw)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))


def test_node2vec_kernel_weighted_alias_path():
    bg, pair = _pair_args(weighted=True)
    rng = np.random.default_rng(3)
    n = 512
    s0, e0 = bg.block_starts[0], bg.block_starts[1]
    cur = jnp.asarray(rng.integers(s0, e0, n).astype(np.int32))
    prev = jnp.asarray(rng.integers(bg.block_starts[2], bg.block_starts[3], n).astype(np.int32))
    hop = jnp.ones(n, jnp.int32)
    active = jnp.ones(n, bool)
    unif = jax.random.uniform(jax.random.PRNGKey(1), (n, 2, 3))
    kw = dict(p=0.5, q=2.0, k_max=2, n_iters=16, has_alias=True)
    zk, mk = node2vec_step_kernel(*pair, prev, cur, hop, active, unif,
                                  interpret=True, **kw)
    zr, mr = node2vec_step_ref(*pair, prev, cur, hop, active, unif, **kw)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))


def test_ops_wrapper_pads_and_dispatches():
    bg, pair = _pair_args()
    rng = np.random.default_rng(0)
    n = 300  # not a multiple of the tile
    s0, e0 = bg.block_starts[0], bg.block_starts[1]
    cur = jnp.asarray(rng.integers(s0, e0, n).astype(np.int32))
    prev = cur
    hop = jnp.zeros(n, jnp.int32)
    active = jnp.ones(n, bool)
    k = jax.random.PRNGKey(0)
    zk, mk = node2vec_step(*pair, prev, cur, hop, active, k,
                           use_kernel=True, interpret=True, walk_tile=256)
    zr, mr = node2vec_step(*pair, prev, cur, hop, active, k, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))
    assert zk.shape == (n,)
    # sampled vertices are real neighbors of cur
    g = bg.graph
    zs = np.asarray(zk)
    for i in range(0, n, 29):
        if mk[i]:
            assert zs[i] in g.neighbors(int(cur[i]))


def test_alias_step_first_order():
    bg, pair = _pair_args()
    rng = np.random.default_rng(0)
    n = 256
    cur = jnp.asarray(
        rng.integers(bg.block_starts[0], bg.block_starts[1], n).astype(np.int32)
    )
    z, moved = alias_step(*pair, cur, jnp.ones(n, bool), jax.random.PRNGKey(2),
                          has_alias=False, interpret=True, walk_tile=256)
    g = bg.graph
    zs = np.asarray(z)
    for i in range(0, n, 17):
        assert zs[i] in g.neighbors(int(cur[i]))


@given(
    n=st.sampled_from([1024, 2048]),
    nb=st.integers(2, 9),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_bucket_hist_property(n, nb, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, nb, n).astype(np.int32))
    valid = jnp.asarray(rng.random(n) < 0.7)
    hk = bucket_hist_kernel(ids, valid, num_buckets=nb, interpret=True)
    hr = bucket_hist_ref(ids, valid, num_buckets=nb)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))
    assert int(hk.sum()) == int(valid.sum())
