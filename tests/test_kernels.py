"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/param sweeps.

The fused advance kernel is validated two independent ways: single-hop
against the dense ``node2vec_step_ref`` oracle fed explicit counter-keyed
uniforms, and multi-hop against the plain jitted ``pair_advance_impl`` —
both bitwise.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from repro.testing import given, settings, st

from repro.core import erdos_renyi, partition_into_n_blocks
from repro.core.graph import BlockView
from repro.engines.base import ResidentPair
from repro.engines.step import advance_pair
from repro.kernels import (
    alias_step,
    bucket_hist_kernel,
    bucket_hist_ref,
    fused_advance_pair,
    node2vec_step,
    node2vec_step_ref,
    rng,
)


def _pair_args(n_verts=500, n_edges=3500, nb=4, b0=0, b1=2, weighted=False, seed=1):
    g = erdos_renyi(n_verts, n_edges, seed=seed)
    if weighted:
        r = np.random.default_rng(seed)
        from repro.core import CSRGraph

        g = CSRGraph(g.indptr, g.indices,
                     (r.random(g.num_edges) + 0.1).astype(np.float32))
    bg = partition_into_n_blocks(g, nb)
    if weighted:
        bg.ensure_alias()
    rp = ResidentPair(bg, has_alias=weighted)
    rp.set_slot(0, BlockView.from_resident(bg.materialize_block(b0)))
    rp.set_slot(1, BlockView.from_resident(bg.materialize_block(b1)))
    pair, v_iters = rp.device_args()
    return bg, pair, v_iters


def _counter_unif(key, wid, hop, k_max):
    """The engine's draw schedule, materialized: (key, wid, hop, round)."""
    kw0, kw1 = rng.fold_in(*rng.fold_in(*rng.key_halves(key), wid), hop)
    return jnp.stack(
        [jnp.stack(rng.uniform3(*rng.fold_in(kw0, kw1, kk)), axis=-1)
         for kk in range(k_max)],
        axis=1,
    )


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (4.0, 0.25), (0.25, 4.0)])
@pytest.mark.parametrize("n_walks", [256, 1024])
def test_fused_single_hop_matches_dense_ref(p, q, n_walks):
    bg, pair, v_iters = _pair_args()
    r = np.random.default_rng(0)
    cur = jnp.asarray(r.integers(bg.block_starts[0], bg.block_starts[1], n_walks).astype(np.int32))
    prev = jnp.asarray(r.integers(bg.block_starts[2], bg.block_starts[3], n_walks).astype(np.int32))
    hop = jnp.asarray(r.integers(0, 6, n_walks).astype(np.int32))
    active = jnp.asarray(r.random(n_walks) < 0.9)
    wid = jnp.asarray(r.integers(0, 1 << 20, n_walks).astype(np.int32))
    key = jax.random.PRNGKey(7)
    kw = dict(p=p, q=q, k_max=4, n_iters=16, v_iters=v_iters)
    zk, mk = node2vec_step(*pair, wid, prev, cur, hop, active, key,
                           use_kernel=True, interpret=True, walk_tile=256, **kw)
    unif = _counter_unif(key, wid, hop, 4)
    zr, mr = node2vec_step_ref(*pair, prev, cur, hop, active, unif,
                               p=p, q=q, k_max=4)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))


def test_fused_kernel_weighted_alias_path():
    bg, pair, v_iters = _pair_args(weighted=True)
    r = np.random.default_rng(3)
    n = 512
    cur = jnp.asarray(r.integers(bg.block_starts[0], bg.block_starts[1], n).astype(np.int32))
    prev = jnp.asarray(r.integers(bg.block_starts[2], bg.block_starts[3], n).astype(np.int32))
    wid = jnp.arange(n, dtype=jnp.int32)
    hop = jnp.ones(n, jnp.int32)
    active = jnp.ones(n, bool)
    key = jax.random.PRNGKey(1)
    kw = dict(p=0.5, q=2.0, k_max=2, n_iters=16, v_iters=v_iters, has_alias=True)
    zk, mk = node2vec_step(*pair, wid, prev, cur, hop, active, key,
                           use_kernel=True, interpret=True, **kw)
    unif = _counter_unif(key, wid, hop, 2)
    zr, mr = node2vec_step_ref(*pair, prev, cur, hop, active, unif,
                               p=0.5, q=2.0, k_max=2, has_alias=True)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))


def test_fused_multi_hop_matches_jax_impl():
    """The tentpole equality: whole multi-hop advance, kernel vs plain jit."""
    bg, pair, v_iters = _pair_args(b0=0, b1=1)
    r = np.random.default_rng(5)
    n = 384  # not a multiple of the tile — exercises lane padding
    cur = jnp.asarray(r.integers(bg.block_starts[0], bg.block_starts[2], n).astype(np.int32))
    prev = jnp.asarray(r.integers(bg.block_starts[0], bg.block_starts[2], n).astype(np.int32))
    hop = jnp.asarray(r.integers(0, 4, n).astype(np.int32))
    alive = jnp.asarray(r.random(n) < 0.95)
    wid = jnp.asarray(r.integers(0, 1 << 20, n).astype(np.int32))
    key = jax.random.PRNGKey(11)
    sc = (jnp.int32(10), jnp.float32(0.9), jnp.float32(4.0), jnp.float32(0.25))
    kw = dict(order=2, k_max=8, n_iters=16, v_iters=v_iters,
              record=True, has_alias=False, max_len=10)
    ref = advance_pair(*pair, wid, prev, cur, hop, alive, key, *sc, **kw)
    fus = fused_advance_pair(*pair, wid, prev, cur, hop, alive, key, *sc, **kw,
                             interpret=True, walk_tile=256)
    for a, b in zip(ref, fus):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_wrapper_pads_and_dispatches():
    bg, pair, v_iters = _pair_args()
    r = np.random.default_rng(0)
    n = 300  # not a multiple of the tile
    cur = jnp.asarray(r.integers(bg.block_starts[0], bg.block_starts[1], n).astype(np.int32))
    prev = cur
    wid = jnp.arange(n, dtype=jnp.int32)
    hop = jnp.zeros(n, jnp.int32)
    active = jnp.ones(n, bool)
    k = jax.random.PRNGKey(0)
    zk, mk = node2vec_step(*pair, wid, prev, cur, hop, active, k,
                           v_iters=v_iters, use_kernel=True,
                           interpret=True, walk_tile=256)
    zr, mr = node2vec_step(*pair, wid, prev, cur, hop, active, k,
                           v_iters=v_iters, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(zk), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    assert zk.shape == (n,)
    # sampled vertices are real neighbors of cur
    g = bg.graph
    zs = np.asarray(zk)
    for i in range(0, n, 29):
        if mk[i]:
            assert zs[i] in g.neighbors(int(cur[i]))


def test_alias_step_first_order():
    bg, pair, v_iters = _pair_args()
    r = np.random.default_rng(0)
    n = 256
    cur = jnp.asarray(
        r.integers(bg.block_starts[0], bg.block_starts[1], n).astype(np.int32)
    )
    wid = jnp.arange(n, dtype=jnp.int32)
    z, moved = alias_step(*pair, wid, cur, jnp.ones(n, bool), jax.random.PRNGKey(2),
                          v_iters=v_iters, has_alias=False,
                          interpret=True, walk_tile=256)
    g = bg.graph
    zs = np.asarray(z)
    for i in range(0, n, 17):
        assert zs[i] in g.neighbors(int(cur[i]))


@given(
    n=st.sampled_from([1024, 2048]),
    nb=st.integers(2, 9),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_bucket_hist_property(n, nb, seed):
    r = np.random.default_rng(seed)
    ids = jnp.asarray(r.integers(0, nb, n).astype(np.int32))
    valid = jnp.asarray(r.random(n) < 0.7)
    hk = bucket_hist_kernel(ids, valid, num_buckets=nb, interpret=True)
    hr = bucket_hist_ref(ids, valid, num_buckets=nb)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))
    assert int(hk.sum()) == int(valid.sum())
