"""Expert-parallel MoE dispatch == dense reference (8 fake devices,
subprocess-isolated).  Covers E % M == 0, E == M, and the virtual-split
path (E_v = E * split), plus gradient flow through the all_to_all pair."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {src!r})
import numpy as np, jax, jax.numpy as jnp
from repro.configs import reduced_config
from repro.models.common import ModelConfig
from repro.models.moe import moe_init, moe_apply, _moe_dense
from repro.sharding.context import activation_rules

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = {{"moe_ep_axis": "model", "moe_dp_axes": ("data",), "mesh": mesh}}
out = {{}}

cases = [
    ("deepseek-v2-236b", {{}}),                                   # epr=2
    ("mixtral-8x22b", {{}}),                                      # E==M
    ("mixtral-8x22b", {{"n_experts": 2, "moe_virtual_split": 2}}),  # split
]
for i, (arch, over) in enumerate(cases):
    cfg = reduced_config(arch)
    cfg = ModelConfig(**{{**cfg.__dict__, "capacity_factor": 8.0, **over}})
    params = moe_init(jax.random.PRNGKey(i), cfg)
    rng = np.random.default_rng(i)
    x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)).astype(np.float32))
    dense, _ = _moe_dense(params, x, cfg)
    with jax.set_mesh(mesh), activation_rules(rules):
        ep, _ = jax.jit(lambda p, xx: moe_apply(p, xx, cfg))(params, x)
        g = jax.jit(jax.grad(lambda p, xx: moe_apply(p, xx, cfg)[0].sum()))(
            params, x
        )
    err = float(jnp.abs(ep - dense).max())
    gn = float(sum(jnp.sum(t.astype(jnp.float32) ** 2)
                   for t in jax.tree.leaves(g))) ** 0.5
    out[f"case{{i}}"] = {{"err": err, "grad_norm": gn}}

print("RESULT " + json.dumps(out))
"""


@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure (jax.sharding.AxisType missing on the "
    "pinned jax); ROADMAP: 'Fix 3 pre-existing failures'",
)
def test_moe_ep_subprocess():
    code = SCRIPT.format(src=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for case, rec in out.items():
        assert rec["err"] < 5e-4, (case, rec)
        assert rec["grad_norm"] > 0 and rec["grad_norm"] < 1e9, (case, rec)
