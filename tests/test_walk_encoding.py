"""128-bit walk record pack/unpack (paper §6.1)."""

import numpy as np
from repro.testing import given, settings, st

from repro.core import WalkBatch, pack_walks, unpack_walks


@given(
    n=st.integers(1, 200),
    nblocks=st.integers(1, 30),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_pack_roundtrip(n, nblocks, seed):
    rng = np.random.default_rng(seed)
    starts = np.concatenate(
        [[0], np.sort(rng.integers(1, 1 << 20, nblocks - 1)), [1 << 20]]
    ) if nblocks > 1 else np.array([0, 1 << 20])
    starts = np.unique(starts)
    V = int(starts[-1])
    batch = WalkBatch(
        src=rng.integers(0, V, n),
        prev=rng.integers(0, V, n),
        cur=rng.integers(0, V, n),
        hop=rng.integers(0, 1024, n).astype(np.int32),
    )
    packed = pack_walks(batch, starts)
    assert packed.shape == (n, 4)
    assert packed.dtype == np.uint32  # 128 bits per walk
    out = unpack_walks(packed, starts)
    np.testing.assert_array_equal(out.src, batch.src)
    np.testing.assert_array_equal(out.prev, batch.prev)
    np.testing.assert_array_equal(out.cur, batch.cur)
    np.testing.assert_array_equal(out.hop, batch.hop)


def test_pack_overflow_detection():
    starts = np.array([0, 10])
    batch = WalkBatch(np.array([1 << 40]), np.array([0]), np.array([0]),
                      np.array([0]))
    try:
        pack_walks(batch, starts)
        assert False, "expected OverflowError"
    except OverflowError:
        pass
