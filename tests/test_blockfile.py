"""On-disk block container (repro.io.blockfile).

Pins the PR's acceptance criteria: a writer→reader roundtrip reproduces the
in-RAM backend bit-for-bit (header, padding, alias tables); on-demand
partial reads return the same rows as full loads and move exactly the bytes
the paper's accounting charges; corrupt/truncated files fail loudly; and
every out-of-core engine is bit-identical and charge-identical across the
RAM and disk graph backends, for both full-load and on-demand loading.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    CSRGraph,
    PlainBucketEngine,
    SOGWEngine,
    erdos_renyi,
    partition_into_n_blocks,
    rwnv_task,
)
from repro.io import (
    BLOCK_FILE_NAME,
    BlockFileError,
    BlockStore,
    DiskBlockedGraph,
    model_ondemand_io,
    plan_reads,
    write_and_open,
    write_block_file,
)
from repro.testing import given, settings, st


@pytest.fixture(scope="module")
def disk_graph(small_blocked, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("blockfile") / BLOCK_FILE_NAME)
    write_block_file(small_blocked, path)
    return path


@pytest.fixture()
def weighted_blocked(small_graph):
    rng = np.random.default_rng(17)
    g = CSRGraph(
        small_graph.indptr, small_graph.indices,
        rng.uniform(0.5, 2.0, small_graph.num_edges).astype(np.float32),
    )
    return partition_into_n_blocks(g, 5)


# ---------------------------------------------------------------------------
# writer -> reader roundtrip
# ---------------------------------------------------------------------------

def test_header_and_metadata_roundtrip(small_blocked, disk_graph):
    with DiskBlockedGraph(disk_graph) as dg:
        assert dg.num_vertices == small_blocked.num_vertices
        assert dg.num_edges == small_blocked.num_edges
        assert dg.num_blocks == small_blocked.num_blocks
        assert dg.max_block_verts == small_blocked.max_block_verts
        assert dg.max_block_edges == small_blocked.max_block_edges
        assert not dg.has_weights
        np.testing.assert_array_equal(dg.block_starts, small_blocked.block_starts)
        np.testing.assert_array_equal(dg.block_nverts, small_blocked.block_nverts)
        np.testing.assert_array_equal(dg.block_nedges, small_blocked.block_nedges)
        np.testing.assert_array_equal(dg.degrees, small_blocked.degrees)
        d_ram = small_blocked.describe()
        d_dsk = dg.describe()
        assert d_ram == d_dsk


def test_blocks_bit_identical_including_padding(small_blocked, disk_graph):
    with DiskBlockedGraph(disk_graph) as dg:
        for b in range(small_blocked.num_blocks):
            ram = small_blocked.materialize_block(b)
            dsk = dg.materialize_block(b)
            assert (dsk.block_id, dsk.start, dsk.nverts, dsk.nedges) == (
                ram.block_id, ram.start, ram.nverts, ram.nedges)
            # padded arrays identical, including the fill values
            np.testing.assert_array_equal(dsk.indptr, ram.indptr)
            np.testing.assert_array_equal(dsk.indices, ram.indices)
            assert dsk.nbytes_full() == ram.nbytes_full()


def test_full_load_bytes_match_fd_reads(small_blocked, disk_graph):
    """The headline property: nbytes_full == bytes read from the fd."""
    with DiskBlockedGraph(disk_graph) as dg:
        total = 0
        for b in range(dg.num_blocks):
            blk = dg.materialize_block(b)
            total += blk.nbytes_full()
        assert dg.data_bytes_read == total
        assert dg.full_loads == dg.num_blocks
        assert dg.aux_bytes_read == 0  # unweighted: no aux arrays on disk


def test_weighted_roundtrip_with_alias_tables(weighted_blocked, tmp_path):
    path = str(tmp_path / BLOCK_FILE_NAME)
    info = write_block_file(weighted_blocked, path)
    assert info["file_bytes"] == os.path.getsize(path)
    weighted_blocked.ensure_alias()
    with DiskBlockedGraph(path) as dg:
        assert dg.has_weights
        dg.ensure_alias()  # present: no-op
        for b in range(dg.num_blocks):
            ram = weighted_blocked.materialize_block(b)
            dsk = dg.materialize_block(b)
            np.testing.assert_array_equal(dsk.alias_j, ram.alias_j)
            np.testing.assert_array_equal(dsk.alias_q, ram.alias_q)
        assert dg.aux_bytes_read == 12 * dg.num_edges


def test_read_csr_reconstruction(small_blocked, disk_graph, weighted_blocked, tmp_path):
    with DiskBlockedGraph(disk_graph) as dg:
        g2 = dg.read_csr()
    g = small_blocked.graph
    np.testing.assert_array_equal(g2.indptr, g.indptr)
    np.testing.assert_array_equal(g2.indices, g.indices)
    assert g2.weights is None
    wpath = str(tmp_path / BLOCK_FILE_NAME)
    write_block_file(weighted_blocked, wpath)
    with DiskBlockedGraph(wpath) as dw:
        gw = dw.read_csr()
    np.testing.assert_array_equal(gw.weights, weighted_blocked.graph.weights)


def test_edge_cut_matches_ram_backend(small_blocked, disk_graph):
    with DiskBlockedGraph(disk_graph) as dg:
        assert dg.edge_cut() == pytest.approx(small_blocked.edge_cut())


# ---------------------------------------------------------------------------
# on-demand partial reads
# ---------------------------------------------------------------------------

def test_ondemand_rows_match_full_load(small_blocked, disk_graph):
    rng = np.random.default_rng(2)
    with DiskBlockedGraph(disk_graph) as dg:
        for b in (0, 2, 4):
            s, e = int(dg.block_starts[b]), int(dg.block_starts[b + 1])
            verts = rng.integers(s, e, size=7)
            rows = dg.read_rows(b, verts)
            full = small_blocked.materialize_block(b)
            for v, seg in rows.items():
                lv = v - s
                rs, re = int(full.indptr[lv]), int(full.indptr[lv + 1])
                np.testing.assert_array_equal(seg, full.indices[rs:re])


def test_ondemand_bytes_match_activated_accounting(small_blocked, disk_graph):
    """read_rows moves exactly activated_load_bytes() bytes through the fd."""
    rng = np.random.default_rng(3)
    with DiskBlockedGraph(disk_graph) as dg:
        s, e = int(dg.block_starts[1]), int(dg.block_starts[2])
        verts = rng.integers(s, e, size=12)  # duplicates dedupe like the charge
        dg.read_rows(1, verts)
        assert dg.ondemand_bytes_read == dg.activated_load_bytes(verts)
        assert dg.activated_load_bytes(verts) == small_blocked.activated_load_bytes(verts)
        assert dg.data_bytes_read == 0  # no full load happened


def test_partial_block_is_activated_view(small_blocked, disk_graph):
    with DiskBlockedGraph(disk_graph) as dg:
        b = 3
        s = int(dg.block_starts[b])
        verts = [s, s + 2, s + 5]
        part = dg.partial_block(b, verts)
        full = small_blocked.materialize_block(b)
        assert part.indptr.shape == full.indptr.shape
        assert part.indices.shape == full.indices.shape
        for lv in range(int(dg.block_nverts[b])):
            seg = part.indices[part.indptr[lv] : part.indptr[lv + 1]]
            if s + lv in verts:
                ref = full.indices[full.indptr[lv] : full.indptr[lv + 1]]
                np.testing.assert_array_equal(seg, ref)
            else:
                assert seg.size == 0  # unrequested rows stay empty


def test_read_rows_rejects_foreign_vertices(disk_graph):
    with DiskBlockedGraph(disk_graph) as dg:
        outside = int(dg.block_starts[2]) + 1  # lives in block 2, not 0
        with pytest.raises(IndexError):
            dg.read_rows(0, [outside])


# ---------------------------------------------------------------------------
# corruption / truncation error paths
# ---------------------------------------------------------------------------

def _copy(path, tmp_path, name):
    dst = str(tmp_path / name)
    with open(path, "rb") as f:
        raw = f.read()
    with open(dst, "wb") as f:
        f.write(raw)
    return dst, raw


def test_bad_magic_rejected(disk_graph, tmp_path):
    dst, raw = _copy(disk_graph, tmp_path, "bad_magic.grb")
    with open(dst, "r+b") as f:
        f.write(b"NOTAGRSW")
    with pytest.raises(BlockFileError, match="magic"):
        DiskBlockedGraph(dst)


def test_bad_version_rejected(disk_graph, tmp_path):
    dst, raw = _copy(disk_graph, tmp_path, "bad_version.grb")
    with open(dst, "r+b") as f:
        f.seek(8)
        f.write((99).to_bytes(4, "little"))
    with pytest.raises(BlockFileError, match="version"):
        DiskBlockedGraph(dst)


def test_truncated_file_rejected_at_open(disk_graph, tmp_path):
    dst, raw = _copy(disk_graph, tmp_path, "trunc.grb")
    with open(dst, "r+b") as f:
        f.truncate(len(raw) - 128)
    with pytest.raises(BlockFileError, match="truncated|size"):
        DiskBlockedGraph(dst)


def test_truncated_header_rejected(disk_graph, tmp_path):
    dst, _ = _copy(disk_graph, tmp_path, "header.grb")
    with open(dst, "r+b") as f:
        f.truncate(40)  # mid-header
    with pytest.raises(BlockFileError, match="truncated"):
        DiskBlockedGraph(dst)


def test_corrupt_block_maxima_rejected(disk_graph, tmp_path):
    dst, _ = _copy(disk_graph, tmp_path, "maxima.grb")
    with open(dst, "r+b") as f:
        f.seek(40)  # header max_block_verts field
        f.write((7).to_bytes(8, "little"))
    with pytest.raises(BlockFileError, match="maxima"):
        DiskBlockedGraph(dst)


def test_corrupt_offset_index_rejected(disk_graph, tmp_path):
    import struct

    from repro.io.blockfile import _HEADER

    dst, raw = _copy(disk_graph, tmp_path, "offsets.grb")
    nb = struct.unpack_from("<Q", raw, 16)[0]
    # first block_offsets entry lives right after header + block_starts
    off = _HEADER.size + 8 * (nb + 1)
    with open(dst, "r+b") as f:
        f.seek(off)
        f.write((12345).to_bytes(8, "little"))
    with pytest.raises(BlockFileError, match="offset index"):
        DiskBlockedGraph(dst)


def test_write_and_open_bootstrap(small_blocked, tmp_path):
    """The launcher/bench one-call path: write into a dir and open."""
    with write_and_open(small_blocked, str(tmp_path)) as dg:
        assert isinstance(dg, DiskBlockedGraph)
        assert dg.path == str(tmp_path / BLOCK_FILE_NAME)
        assert dg.num_edges == small_blocked.num_edges
    with write_and_open(small_blocked) as dg2:  # fresh temp dir
        assert os.path.exists(dg2.path)
        assert dg2.path != str(tmp_path / BLOCK_FILE_NAME)


def test_writer_cleans_up_temp_on_failure(small_blocked, tmp_path, monkeypatch):
    """An interrupted write leaves neither the target nor a stray temp."""
    import repro.io.blockfile as bf

    def boom(src, dst):
        raise RuntimeError("injected failure")

    monkeypatch.setattr(bf.os, "replace", boom)
    target = tmp_path / BLOCK_FILE_NAME
    with pytest.raises(RuntimeError, match="injected failure"):
        write_block_file(small_blocked, str(target))
    assert list(tmp_path.iterdir()) == []  # no target, no .tmp leftovers


# ---------------------------------------------------------------------------
# engines: bit-identical walks + identical deterministic I/O across backends
# ---------------------------------------------------------------------------

def _strip_wall_clock(stats):
    # writer_queue_peak is enqueue-time queue depth — timing-dependent by
    # design (docs/execution.md: "don't pin it"), so it is stripped
    # alongside the wall-clock timers before the strict equality check
    d = stats.as_dict()
    for k in ("exec_time", "sim_wall_time", "writer_queue_peak"):
        d.pop(k)
    return d


@pytest.mark.parametrize("loading", ["full", "ondemand", "auto"])
def test_biblock_bit_identical_ram_vs_disk(small_blocked, disk_graph, loading):
    """The acceptance criterion: BiBlockEngine on DiskBlockedGraph (full-load
    AND on-demand) == the in-RAM BlockedGraph, walks and counters."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    r_ram = BiBlockEngine(small_blocked, task, loading=loading).run()
    with DiskBlockedGraph(disk_graph) as dg:
        r_dsk = BiBlockEngine(dg, task, loading=loading).run()
        np.testing.assert_array_equal(r_ram.endpoint_counts, r_dsk.endpoint_counts)
        assert _strip_wall_clock(r_ram.stats) == _strip_wall_clock(r_dsk.stats)
        assert dg.data_bytes_read > 0  # the disk run really hit the fd


@pytest.mark.parametrize("Engine", [PlainBucketEngine, SOGWEngine])
def test_baseline_engines_bit_identical_ram_vs_disk(small_blocked, disk_graph, Engine):
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    r_ram = Engine(small_blocked, task).run()
    with DiskBlockedGraph(disk_graph) as dg:
        r_dsk = Engine(dg, task).run()
    np.testing.assert_array_equal(r_ram.endpoint_counts, r_dsk.endpoint_counts)
    assert _strip_wall_clock(r_ram.stats) == _strip_wall_clock(r_dsk.stats)


def test_weighted_biblock_bit_identical(weighted_blocked, tmp_path):
    path = str(tmp_path / BLOCK_FILE_NAME)
    write_block_file(weighted_blocked, path)
    task = rwnv_task(p=2.0, q=0.5, walks_per_vertex=1, length=8, seed=5)
    r_ram = BiBlockEngine(weighted_blocked, task).run()
    with DiskBlockedGraph(path) as dg:
        r_dsk = BiBlockEngine(dg, task).run()
    np.testing.assert_array_equal(r_ram.endpoint_counts, r_dsk.endpoint_counts)


# ---------------------------------------------------------------------------
# gap-aware read planner (repro.io.ioplan)
# ---------------------------------------------------------------------------

def test_empty_ondemand_read_not_counted(disk_graph):
    """Regression: a zero-vertex request issues no pread and counts nothing."""
    with DiskBlockedGraph(disk_graph) as dg:
        assert dg.read_rows(1, []) == {}
        assert dg.ondemand_reads == 0
        assert dg.ondemand_syscalls == 0
        assert dg.ondemand_bytes_read == 0
        view = dg.partial_view(1, [])
        assert view.nverts == 0
        assert dg.ondemand_reads == 0
        # a non-empty request still counts exactly one on-demand read
        dg.read_rows(1, [int(dg.block_starts[1])])
        assert dg.ondemand_reads == 1


@given(
    gap=st.sampled_from([0, 1, 64, 4096, 1 << 20]),
    seed=st.integers(0, 10_000),
    weighted=st.booleans(),
)
@settings(max_examples=20, deadline=None)
def test_planner_matches_per_vertex_reference(gap, seed, weighted):
    """Satellite property: for random graphs and random gap budgets the
    planner returns the same rows/alias segments and charges the same
    useful bytes as the per-vertex reference, with no more syscalls — and
    zero coalescing at ``gap_bytes=0``."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 120))
    g = erdos_renyi(n, int(rng.integers(n, 6 * n)), seed=seed)
    if weighted:
        g = CSRGraph(
            g.indptr, g.indices,
            rng.uniform(0.5, 2.0, g.num_edges).astype(np.float32),
        )
    bg = partition_into_n_blocks(g, int(rng.integers(2, 6)))
    if weighted:
        bg.ensure_alias()
    verts = rng.integers(0, n, size=int(rng.integers(1, 3 * n)))
    # tempfile instead of a pytest fixture: @given bodies cannot take
    # function-scoped fixtures (hypothesis health check / fallback shim)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, BLOCK_FILE_NAME)
        write_block_file(bg, path)
        _check_planner_vs_reference(path, verts, gap, weighted)


def _check_planner_vs_reference(path, verts, gap, weighted):
    with DiskBlockedGraph(path) as ref, DiskBlockedGraph(path, io_coalesce_gap=gap) as pln:
        v_ref = ref.gather_view(verts)
        v_pln = pln.gather_view(verts)
        np.testing.assert_array_equal(v_pln.vids, v_ref.vids)
        np.testing.assert_array_equal(v_pln.indptr, v_ref.indptr)
        np.testing.assert_array_equal(v_pln.indices, v_ref.indices)
        if weighted:
            np.testing.assert_array_equal(v_pln.alias_j, v_ref.alias_j)
            np.testing.assert_array_equal(v_pln.alias_q, v_ref.alias_q)
        assert pln.ondemand_bytes_read == ref.ondemand_bytes_read
        assert pln.ondemand_bytes_read == ref.activated_load_bytes(verts)
        assert pln.aux_bytes_read == ref.aux_bytes_read
        assert pln.ondemand_syscalls <= ref.ondemand_syscalls
        if gap == 0:
            # planner off: bit-for-bit the reference path
            assert pln.ondemand_syscalls == ref.ondemand_syscalls
            assert pln.coalesced_ranges == 0
            assert pln.coalesce_waste_bytes == 0
        # the pure model predicts the real executor exactly
        assert model_ondemand_io(ref, verts, gap) == (
            pln.ondemand_syscalls,
            pln.coalesced_ranges,
            pln.coalesce_waste_bytes,
        )


@given(seed=st.integers(0, 10_000), gap=st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_plan_reads_moves_the_extent_union(seed, gap):
    """plan_reads covers every extent, never splits one, and its waste is
    exactly total-minus-union — 0 at gap 0."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 40))
    starts = np.sort(rng.integers(0, 2000, size=k))
    ends = starts + rng.integers(0, 60, size=k)
    plan = plan_reads(starts, ends, gap)
    union = np.zeros(int(ends.max()) + 1 if k else 0, bool)
    for s0, e0 in zip(starts, ends):
        union[s0:e0] = True
    covered = np.zeros_like(union)
    for s0, e0 in plan.ranges:
        covered[s0:e0] = True
    assert covered[union].all()  # every useful byte is read
    assert plan.useful_bytes == int(union.sum())
    assert plan.waste_bytes == plan.total_bytes - plan.useful_bytes
    if gap == 0:
        assert plan.waste_bytes == 0  # only overlap/adjacency merges
    for k_, (s0, e0) in enumerate(zip(starts, ends)):
        r = int(plan.seg_range[k_])
        if e0 == s0:
            assert r == -1  # empty extents read nothing
        else:
            assert plan.ranges[r, 0] <= s0 and e0 <= plan.ranges[r, 1]


@pytest.mark.parametrize("gap", [1, 4096, 1 << 20])
def test_coalesced_walks_and_charges_bit_identical(small_blocked, disk_graph, gap):
    """Engine gate: with the planner on, walks and every charged counter
    except the syscall/range/waste gauges (and the coalesce-aware modelled
    on-demand time) are identical to the gap-0 reference — on both
    backends — and the disk run's real planner counters equal the charged
    gauges when prefetch is off."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    ref = BiBlockEngine(small_blocked, task, loading="ondemand", prefetch=False).run()
    try:
        small_blocked.io_coalesce_gap = gap
        r_ram = BiBlockEngine(small_blocked, task, loading="ondemand", prefetch=False).run()
    finally:
        small_blocked.io_coalesce_gap = 0  # session-scoped fixture
    with DiskBlockedGraph(disk_graph, io_coalesce_gap=gap) as dg:
        r_dsk = BiBlockEngine(dg, task, loading="ondemand", prefetch=False).run()
        real = dg.counters()
    for r in (r_ram, r_dsk):
        np.testing.assert_array_equal(r.endpoint_counts, ref.endpoint_counts)
        assert r.stats.ondemand_bytes == ref.stats.ondemand_bytes
        assert r.stats.ondemand_ios == ref.stats.ondemand_ios
        assert r.stats.ondemand_syscalls < ref.stats.ondemand_syscalls
        assert r.stats.coalesced_ranges > 0
    # the planner is backend-invariant: ram and disk charge identically
    assert _strip_wall_clock(r_ram.stats) == _strip_wall_clock(r_dsk.stats)
    # honest accounting: with prefetch off the real preads equal the gauges
    assert real["ondemand_syscalls"] == r_dsk.stats.ondemand_syscalls
    assert real["coalesced_ranges"] == r_dsk.stats.coalesced_ranges
    assert real["coalesce_waste_bytes"] == r_dsk.stats.coalesce_waste_bytes
    assert real["ondemand_bytes_read"] == r_dsk.stats.ondemand_bytes


def test_schedule_batches_same_block_partials(small_blocked, disk_graph):
    """BlockStore.schedule unions same-slot partial requests per block into
    one prefetched build (one plan per block, not one per request)."""
    from repro.core import IOStats

    with DiskBlockedGraph(disk_graph) as dg:
        store = BlockStore(dg, IOStats(), capacity=2, enable_prefetch=True)
        s1 = int(dg.block_starts[1])
        store.schedule([
            ("partial", 1, np.array([s1, s1 + 2])),
            ("partial", 1, np.array([s1 + 1, s1 + 2])),
            ("full", 0),
        ])
        assert store.partial_prefetch_issued == 1
        view = store.partial_view(1, np.array([s1, s1 + 1, s1 + 2]))
        np.testing.assert_array_equal(view.vids, [s1, s1 + 1, s1 + 2])
        assert store.partial_prefetch_hits == 1  # the union served as base
        store.close()


def test_blockstore_lru_hides_rereads(small_blocked, disk_graph):
    """With a capacity-2 LRU the disk backend re-reads evicted blocks; the
    charged I/O stays deterministic while real reads track evictions."""
    from repro.core import IOStats

    with DiskBlockedGraph(disk_graph) as dg:
        stats = IOStats()
        store = BlockStore(dg, stats, capacity=2, enable_prefetch=False)
        store.get(0), store.get(0)  # second get served from LRU: one real read
        assert dg.full_loads == 1
        assert stats.block_ios == 2  # but both gets are charged (deterministic)
        store.get(1), store.get(2), store.get(0)  # 0 evicted -> re-read
        assert dg.full_loads == 4
        store.close()
