"""Skewed storage, Eq.4 bucketing, triangular scheduling (paper §4)."""

import numpy as np
from repro.testing import given, settings, st

from repro.core import (
    WalkBatch,
    bucket_ids,
    make_scheduler,
    skewed_block_assignment,
    split_into_buckets,
    standard_block_io_bound,
    traditional_block_assignment,
    triangular_block_io_bound,
    triangular_pairs,
)


def _random_batch(rng, n, V):
    return WalkBatch(
        rng.integers(0, V, n), rng.integers(0, V, n),
        rng.integers(0, V, n), rng.integers(0, 100, n).astype(np.int32),
    )


@given(nb=st.integers(2, 40))
@settings(max_examples=30, deadline=None)
def test_triangular_bound_formula(nb):
    # Eq. 3: enumerate the schedule and count loads
    total = 0
    currents = 0
    for b, ancs in triangular_pairs(nb):
        currents += 1
        total += len(ancs)
    assert currents == nb - 1
    assert currents + total == triangular_block_io_bound(nb)
    assert standard_block_io_bound(nb) == nb * nb
    # ~50% saving for large nb (Eq. 2 vs Eq. 3)
    if nb >= 10:
        assert triangular_block_io_bound(nb) / standard_block_io_bound(nb) < 0.6


@given(
    n=st.integers(1, 300),
    seed=st.integers(0, 99),
)
@settings(max_examples=25, deadline=None)
def test_skewed_assignment_is_min(n, seed):
    rng = np.random.default_rng(seed)
    starts = np.array([0, 100, 250, 400, 600])
    batch = _random_batch(rng, n, 600)
    assoc = skewed_block_assignment(starts, batch)
    trad = traditional_block_assignment(starts, batch)
    from repro.core import block_of

    bp = block_of(starts, batch.prev)
    bc = block_of(starts, batch.cur)
    np.testing.assert_array_equal(assoc, np.minimum(bp, bc))
    np.testing.assert_array_equal(trad, bc)


@given(n=st.integers(1, 300), seed=st.integers(0, 99), b=st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_bucket_rule_eq4(n, seed, b):
    rng = np.random.default_rng(seed)
    starts = np.array([0, 100, 250, 400, 600])
    batch = _random_batch(rng, n, 600)
    ids = bucket_ids(starts, batch, b)
    from repro.core import block_of

    bp = block_of(starts, batch.prev)
    bc = block_of(starts, batch.cur)
    np.testing.assert_array_equal(ids, np.where(bp == b, bc, bp))
    # and the wid-aligned dict split preserves every walk exactly once
    wid = rng.permutation(n).astype(np.int64)
    buckets = split_into_buckets(starts, batch, b, wid)
    assert sum(len(bb) for bb, _ in buckets.values()) == n
    seen = np.concatenate([w for _, w in buckets.values()])
    np.testing.assert_array_equal(np.sort(seen), np.sort(wid))
    for bid, (bb, bw) in buckets.items():
        np.testing.assert_array_equal(bucket_ids(starts, bb, b), bid)
        # wid stays aligned with its walk: check via the cur field
        pos = {int(w): k for k, w in enumerate(wid)}
        np.testing.assert_array_equal(
            bb.cur, batch.cur[[pos[int(w)] for w in bw]]
        )


def test_schedulers_drain():
    counts = np.array([5, 0, 3, 9])
    hops = np.array([2.0, np.inf, 1.0, 7.0])
    assert make_scheduler("iteration", 4).next_block(counts, hops) == 0
    assert make_scheduler("max_sum", 4).next_block(counts, hops) == 3
    assert make_scheduler("min_height", 4).next_block(counts, hops) == 2
    alpha = make_scheduler("alphabet", 4)
    assert [alpha.next_block(counts, hops) for _ in range(4)] == [0, 1, 2, 3]
    it = make_scheduler("iteration", 4)
    seq = [it.next_block(counts, hops) for _ in range(3)]
    assert seq == [0, 2, 3]  # skips empty block 1
    # all return None when no walks remain
    zero = np.zeros(4)
    for name in ("iteration", "alphabet", "max_sum", "min_height", "graphwalker"):
        assert make_scheduler(name, 4).next_block(zero, hops) is None
