"""Alias tables, binary-search membership, node2vec acceptance."""

import numpy as np
import jax
import jax.numpy as jnp
from repro.testing import given, settings, st

from repro.core.sampling import (
    alias_draw,
    build_alias,
    build_alias_rows,
    membership,
    node2vec_accept_prob,
)


@given(
    n=st.integers(1, 64),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_alias_distribution_matches(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.random(n) + 0.01
    J, q = build_alias(w)
    # exact check: alias tables encode p_i = (q_i + sum_{j: J_j = i} (1 - q_j)) / n
    p = q.astype(np.float64).copy()
    for j in range(n):
        if J[j] != j:
            p[J[j]] += 1.0 - q[j]
    p /= n
    np.testing.assert_allclose(p, w / w.sum(), atol=1e-6)


def test_alias_draw_statistics():
    w = np.array([1.0, 2.0, 3.0, 6.0])
    J, q = build_alias(w)
    n = 200_000
    k = jax.random.PRNGKey(0)
    u1, u2 = jax.random.uniform(k, (2, n))
    rs = jnp.zeros(n, jnp.int32)
    deg = jnp.full(n, 4, jnp.int32)
    draws = alias_draw(jnp.asarray(J), jnp.asarray(q), rs, deg, u1, u2)
    freq = np.bincount(np.asarray(draws), minlength=4) / n
    np.testing.assert_allclose(freq, w / w.sum(), atol=0.01)


@given(
    row=st.lists(st.integers(0, 1000), min_size=0, max_size=50),
    probe=st.integers(0, 1000),
)
@settings(max_examples=60, deadline=None)
def test_membership_binary_search(row, probe):
    row_sorted = np.unique(np.array(row, dtype=np.int32))
    pad = np.full(64, -1, np.int32)
    pad[: len(row_sorted)] = row_sorted
    got = membership(
        jnp.asarray(pad),
        jnp.zeros(1, jnp.int32),
        jnp.full(1, len(row_sorted), jnp.int32),
        jnp.full(1, probe, jnp.int32),
        n_iters=8,
    )
    assert bool(got[0]) == (probe in row_sorted.tolist())


def test_node2vec_accept_prob_cases():
    p, q = 2.0, 0.5
    M = max(1.0, 1 / p, 1 / q)  # = 2
    z = jnp.array([5, 7, 9])
    u = jnp.array([5, 5, 5])
    is_nb = jnp.array([False, True, False])
    acc = node2vec_accept_prob(z, u, is_nb, p, q)
    np.testing.assert_allclose(
        np.asarray(acc), [1 / p / M, 1.0 / M, 1 / q / M], atol=1e-6
    )


def test_build_alias_rows_pads_identity():
    indptr = np.array([0, 2, 2, 5], np.int32)
    J, q = build_alias_rows(indptr, 3, 8, None)
    assert J.shape == (8,)
    # unweighted: q == 1 everywhere (uniform -> no alias redirection)
    np.testing.assert_allclose(q, 1.0)
