"""The CI pipeline contract: .github/workflows/ci.yml stays aligned with the
ROADMAP tier-1 command, the test matrix, the lint config, and the bench-smoke
artifact — so a workflow edit that would silently drop a leg fails here first.
"""

from pathlib import Path

import pytest

yaml = pytest.importorskip("yaml", reason="workflow validation needs PyYAML")

REPO = Path(__file__).resolve().parents[1]
WORKFLOW = REPO / ".github" / "workflows" / "ci.yml"


@pytest.fixture(scope="module")
def wf():
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


def _runs(job):
    return [s.get("run", "") for s in job["steps"]]


def test_workflow_parses_and_has_all_jobs(wf):
    assert set(wf["jobs"]) == {"test", "lint", "bench-smoke"}
    # `on:` parses to the boolean True key in YAML 1.1
    triggers = wf.get("on") or wf.get(True)
    assert "push" in triggers and "pull_request" in triggers


def test_matrix_covers_python_versions_and_hypothesis_legs(wf):
    m = wf["jobs"]["test"]["strategy"]["matrix"]
    assert m["python-version"] == ["3.10", "3.12"]
    assert sorted(m["hypothesis"]) == ["no", "yes"]
    # pip caching on every setup-python
    for job in wf["jobs"].values():
        for step in job["steps"]:
            if "setup-python" in str(step.get("uses", "")):
                assert step["with"].get("cache") == "pip"


def test_tier1_command_matches_roadmap(wf):
    tier1 = "PYTHONPATH=src python -m pytest -x -q"
    assert any(tier1 in r for r in _runs(wf["jobs"]["test"]))
    assert tier1.split("PYTHONPATH=src ")[1] in (REPO / "ROADMAP.md").read_text()


def test_fallback_shim_leg_asserts_no_hypothesis(wf):
    steps = wf["jobs"]["test"]["steps"]
    legs = {s.get("if", ""): s for s in steps if "matrix.hypothesis" in s.get("if", "")}
    assert any("== 'yes'" in c for c in legs)
    no_leg = next(s for c, s in legs.items() if "== 'no'" in c)
    assert "HAVE_HYPOTHESIS" in no_leg["run"]


def test_lint_job_runs_ruff_check_and_format(wf):
    runs = _runs(wf["jobs"]["lint"])
    assert any(r.strip().startswith("ruff check") for r in runs)
    assert any("ruff format --check" in r for r in runs)
    # the docs contract rides the lint job: links resolve, named repro.*
    # module paths and CLI flags exist (stdlib-only, runs without deps)
    assert any("scripts/check_docs.py" in r for r in runs)
    # and the matching config exists in pyproject
    py = (REPO / "pyproject.toml").read_text()
    assert "[tool.ruff]" in py and "[tool.ruff.lint]" in py


def test_bench_smoke_runs_matrix_and_uploads_artifact(wf):
    job = wf["jobs"]["bench-smoke"]
    runs = _runs(job)
    assert any("backend_matrix" in r and "--json" in r for r in runs)
    # the async-pipeline overlap entry (identical CRC + nonzero overlapped
    # bytes + fewer stall slots than the serial run) rides the same job
    assert any("pipeline_overlap" in r and "--json" in r for r in runs)
    # ... and so does the sharded-pool entry (identical CRCs + invariant
    # charges across pool_shards {1,2,4,8}, real per-shard writers)
    assert any("sharded_pool" in r and "--json" in r for r in runs)
    # ... and the coalesced-I/O entry (gap-aware read planner: identical
    # walks + charged useful bytes, strictly fewer on-demand syscalls,
    # us_per_call at gap 0 / 4 KiB / 64 KiB in the report)
    assert any("coalesced_io" in r and "--json" in r for r in runs)
    # ... and the fused-advance entry (pallas vs jax advance: identical walk
    # CRCs and charges, us_per_call for both impls in the report)
    assert any("fused_advance" in r and "--json" in r for r in runs)
    # ... and the query-serving entry (served answers bit-identical to
    # direct batch runs; hot-set pinning strictly cheaper than pure LRU)
    assert any("query_serving" in r and "--json" in r for r in runs)
    assert any("--pool disk" in r and "--graph-backend disk" in r for r in runs)
    uploads = [s for s in job["steps"] if "upload-artifact" in str(s.get("uses", ""))]
    assert len(uploads) == 1
    assert "bench-report.json" in uploads[0]["with"]["path"]
    assert uploads[0]["with"]["if-no-files-found"] == "error"


def test_all_actions_are_version_pinned(wf):
    for job in wf["jobs"].values():
        for step in job["steps"]:
            uses = step.get("uses")
            if uses:
                assert "@v" in uses, f"unpinned action: {uses}"
