"""Every examples/*.py entry point runs end to end on a tiny configuration.

Each example exposes size arguments exactly so this smoke can exist: the
full code path (graph build -> engine/serving -> readout, or model init ->
train/decode) executes in seconds, and a refactor that breaks an example's
imports or argument surface fails here instead of on a reader's machine.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
EXAMPLES = REPO / "examples"

TINY_ARGS = {
    "quickstart.py": ["--vertices", "300", "--blocks", "4", "--length", "8"],
    "pagerank_query.py": [
        "--vertices", "300", "--blocks", "4", "--samples", "16", "--length", "6",
    ],
    "train_lm_on_walks.py": [
        "--tiny", "--steps", "3", "--vertices", "200", "--batch", "2", "--seq", "8",
    ],
    "serve_lm.py": ["--batch", "1", "--prompt-len", "4", "--new-tokens", "2"],
}


def test_every_example_has_tiny_args():
    scripts = sorted(p.name for p in EXAMPLES.glob("*.py"))
    assert scripts == sorted(TINY_ARGS), (
        f"examples/ and the smoke matrix diverged: {scripts} vs {sorted(TINY_ARGS)}"
    )


@pytest.mark.parametrize("script", sorted(TINY_ARGS))
def test_example_runs(script, tmp_path):
    args = list(TINY_ARGS[script])
    if script == "train_lm_on_walks.py":
        args += ["--ckpt-dir", str(tmp_path / "ckpt")]
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"), JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / script), *args],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=REPO,
    )
    assert proc.returncode == 0, (
        f"{script} failed\nstdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
    )
    assert proc.stdout.strip(), f"{script} printed nothing"
