"""Custom-VJP flash attention vs naive softmax attention (fwd + grads)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention


def naive(q, k, v, causal=True, window=None):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize(
    "causal,window,qc,kc,S",
    [
        (True, None, 32, 32, 96),
        (True, None, 64, 16, 96),
        (True, 16, 32, 32, 96),
        (True, 24, 16, 48, 120),
        (False, None, 48, 24, 96),
        (True, None, 128, 128, 100),  # padding path (S not chunk multiple)
    ],
)
def test_flash_matches_naive(causal, window, qc, kc, S):
    rng = np.random.default_rng(0)
    B, H, D = 2, 3, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    o1 = chunked_attention(q, k, v, causal=causal, window=window,
                           q_chunk=qc, kv_chunk=kc)
    o2 = naive(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5,
                               rtol=3e-5)
    # gradients through the custom VJP
    f1 = lambda *a: chunked_attention(*a, causal=causal, window=window,
                                      q_chunk=qc, kv_chunk=kc).sum()
    f2 = lambda *a: naive(*a, causal=causal, window=window).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   rtol=5e-4, err_msg=f"d{name}")


def test_flash_banded_is_subquadratic_in_tiles():
    """The banded path must touch ceil((Cq+W)/Ck)+1 kv chunks per q chunk,
    not all of them — check via the compiled HLO trip count."""
    import re

    B, S, H, D, W = 1, 1024, 2, 8, 64
    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)
    f = lambda q, k, v: chunked_attention(q, k, v, causal=True, window=W,
                                          q_chunk=64, kv_chunk=64)
    txt = jax.jit(f).lower(q, q, q).compile().as_text()
    # inner kv loop bound should be 3 (=(64+64)/64+1), not 16
    bounds = [int(x) for x in re.findall(r"constant\((\d+)\)", txt)]
    assert 3 in bounds and S // 64 in bounds
