import sys
from pathlib import Path

# package import without install
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

from repro.core import CSRGraph, erdos_renyi, partition_into_n_blocks


@pytest.fixture(scope="session")
def small_graph():
    return erdos_renyi(600, 4800, seed=11)


@pytest.fixture(scope="session")
def small_blocked(small_graph):
    return partition_into_n_blocks(small_graph, 5)


@pytest.fixture(scope="session")
def tiny_graph():
    # 12-vertex connected graph with known structure
    rng = np.random.default_rng(5)
    edges = [(i, (i + 1) % 12) for i in range(12)]
    edges += [(i, (i + 3) % 12) for i in range(12)]
    edges += [(0, 6), (2, 9), (4, 10)]
    return CSRGraph.from_edges(np.array(edges), 12)
