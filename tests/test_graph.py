"""CSR graph + blocked storage invariants (unit + hypothesis property)."""

import numpy as np
from repro.testing import given, settings, st

from repro.core import (
    CSRGraph,
    block_of,
    erdos_renyi,
    greedy_locality_partition,
    partition_into_n_blocks,
    sequential_partition,
)


def test_csr_from_edges_symmetric():
    g = CSRGraph.from_edges(np.array([[0, 1], [1, 2], [2, 0]]), 4)
    assert g.num_vertices == 4
    # symmetrized: each edge twice
    assert g.num_edges == 6
    assert list(g.neighbors(0)) == [1, 2]
    assert list(g.neighbors(3)) == []


def test_csr_rows_sorted(small_graph):
    for v in range(0, small_graph.num_vertices, 37):
        nb = small_graph.neighbors(v)
        assert np.all(np.diff(nb) > 0), "rows must be strictly sorted (dedup)"


def test_no_self_loops(small_graph):
    for v in range(0, small_graph.num_vertices, 23):
        assert v not in small_graph.neighbors(v)


@given(
    n=st.integers(8, 200),
    m=st.integers(10, 600),
    nb=st.integers(1, 7),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_partition_covers_all_vertices(n, m, nb, seed):
    g = erdos_renyi(n, m, seed=seed)
    bg = partition_into_n_blocks(g, nb)
    assert bg.block_starts[0] == 0
    assert bg.block_starts[-1] == g.num_vertices
    assert np.all(np.diff(bg.block_starts) > 0)
    # every vertex belongs to exactly one block
    vs = np.arange(g.num_vertices)
    b = block_of(bg.block_starts, vs)
    assert b.min() >= 0 and b.max() < bg.num_blocks


def test_sequential_partition_respects_budget(small_graph):
    budget = 20_000
    bg = sequential_partition(small_graph, budget)
    for b in range(bg.num_blocks):
        blk = bg.materialize_block(b)
        if blk.nverts > 1:  # single-vertex blocks may exceed by necessity
            assert blk.nbytes_full() <= budget


def test_materialize_block_roundtrip(small_blocked):
    g = small_blocked.graph
    for b in range(small_blocked.num_blocks):
        blk = small_blocked.materialize_block(b)
        for off, v in enumerate(
            range(blk.start, blk.start + min(blk.nverts, 17))
        ):
            lo, hi = blk.indptr[off], blk.indptr[off + 1]
            np.testing.assert_array_equal(
                blk.indices[lo:hi], g.neighbors(v)
            )


def test_greedy_partition_lowers_edge_cut():
    g = erdos_renyi(400, 3000, seed=2)
    seq = partition_into_n_blocks(g, 4)
    _, bg, perm = greedy_locality_partition(g, 4, rounds=2, seed=0)
    # permutation must be a bijection
    assert sorted(perm.tolist()) == list(range(g.num_vertices))
    assert bg.edge_cut() <= seq.edge_cut() + 0.05


def test_activated_load_bytes(small_blocked):
    g = small_blocked.graph
    vs = np.array([0, 1, 1, 5])
    expect = 8 * 3 + 4 * int(
        g.out_degree(np.array([0, 1, 5])).sum()
    )
    assert small_blocked.activated_load_bytes(vs) == expect
