"""Engine correctness + the paper's I/O claims at test scale.

The decisive correctness check: the *empirical second-order transition
frequencies* of walks produced by each engine match the analytic Node2vec
edge-edge distribution (Eq. 1) — engines may differ in I/O but must sample
the same process.
"""

import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    InMemoryWalker,
    PlainBucketEngine,
    SOGWEngine,
    block_of,
    deepwalk_task,
    partition_into_n_blocks,
    prnv_task,
    rwnv_task,
)


def analytic_step_probs(g, u, v, p, q):
    nb = g.neighbors(v)
    w = np.ones(len(nb))
    for i, z in enumerate(nb):
        if z == u:
            w[i] = 1.0 / p
        elif z in g.neighbors(u):
            w[i] = 1.0
        else:
            w[i] = 1.0 / q
    return nb, w / w.sum()


def transition_frequencies(corpus, g, p, q, max_pairs=40):
    """Chi-square-ish comparison of observed next-vertex freqs vs Eq. 1."""
    from collections import Counter, defaultdict

    obs = defaultdict(Counter)
    for row in corpus:
        row = row[row >= 0]
        for t in range(1, len(row) - 1):
            obs[(row[t - 1], row[t])][row[t + 1]] += 1
    checked = 0
    for (u, v), counter in sorted(obs.items(), key=lambda kv: -sum(kv[1].values())):
        total = sum(counter.values())
        if total < 400:
            continue
        nb, probs = analytic_step_probs(g, u, v, p, q)
        emp = np.array([counter.get(z, 0) for z in nb]) / total
        np.testing.assert_allclose(emp, probs, atol=6 * np.sqrt(probs.max() / total) + 0.02)
        checked += 1
        if checked >= max_pairs:
            break
    assert checked > 0, "no (u,v) pair had enough visits to test"


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (4.0, 0.25)])
def test_inmemory_matches_analytic_transition(tiny_graph, p, q):
    task = rwnv_task(p=p, q=q, walks_per_vertex=400, length=12, seed=3)
    bg = partition_into_n_blocks(tiny_graph, 3)
    res = InMemoryWalker(bg, task).run()
    transition_frequencies(res.corpus, tiny_graph, p, q)


@pytest.mark.parametrize("p,q", [(1.0, 1.0), (0.5, 2.0)])
def test_biblock_matches_analytic_transition(tiny_graph, p, q):
    task = rwnv_task(p=p, q=q, walks_per_vertex=400, length=10, seed=4)
    bg = partition_into_n_blocks(tiny_graph, 3)
    res = BiBlockEngine(bg, task, record_walks=True).run()
    transition_frequencies(res.corpus, tiny_graph, p, q)


def test_all_walks_complete(small_blocked):
    task = rwnv_task(walks_per_vertex=2, length=12, seed=0)
    for Engine in (BiBlockEngine, PlainBucketEngine, SOGWEngine):
        res = Engine(small_blocked, task).run()
        assert res.stats.steps_sampled == res.num_walks * task.length, Engine
        assert res.endpoint_counts.sum() == res.num_walks


def test_biblock_beats_pb_block_ios(small_blocked):
    """Paper Table 3: Bi-Block cuts block I/Os to ~50% of plain bucket."""
    task = rwnv_task(walks_per_vertex=2, length=12, seed=0)
    r_bb = BiBlockEngine(small_blocked, task).run()
    r_pb = PlainBucketEngine(small_blocked, task).run()
    ratio = r_bb.stats.block_ios / max(r_pb.stats.block_ios, 1)
    assert ratio < 0.75, f"expected ~0.5, got {ratio:.2f}"
    # and simulated I/O time improves at least as much
    assert r_bb.stats.sim_block_io_time < r_pb.stats.sim_block_io_time


def test_sogw_pays_vertex_ios_biblock_does_not(small_blocked):
    """Paper Fig. 1(a): second-order on SOGW is dominated by vertex I/Os."""
    task = rwnv_task(walks_per_vertex=2, length=12, seed=0)
    r_so = SOGWEngine(small_blocked, task).run()
    r_bb = BiBlockEngine(small_blocked, task).run()
    assert r_so.stats.vertex_ios > 10 * max(r_bb.stats.vertex_ios, 1)
    assert r_bb.stats.vertex_ios == 0


def test_sgsc_cache_reduces_vertex_ios(small_blocked):
    task = rwnv_task(walks_per_vertex=2, length=12, seed=0)
    r_so = SOGWEngine(small_blocked, task).run()
    r_sg = SOGWEngine(small_blocked, task, static_cache=True).run()
    assert r_sg.stats.vertex_ios < r_so.stats.vertex_ios


def test_prnv_terminates_and_estimates(small_blocked):
    g = small_blocked.graph
    task = prnv_task(7, g.num_vertices, samples_per_vertex=1, seed=1)
    res = BiBlockEngine(small_blocked, task).run()
    assert res.endpoint_counts.sum() == res.num_walks
    ppr = res.ppr_estimate()
    assert abs(ppr.sum() - 1.0) < 1e-9
    # restart decay=0.85, max len 20: mean hops ~ geometric, well below max
    assert res.stats.steps_sampled < res.num_walks * task.length


def test_first_order_deepwalk(small_blocked):
    """Paper §7.8: the engine also runs first-order tasks."""
    task = deepwalk_task(walks_per_vertex=2, length=10, seed=0)
    res = BiBlockEngine(small_blocked, task).run()
    assert res.stats.steps_sampled == res.num_walks * task.length


def test_skewed_pool_invariant(small_blocked):
    """App. B: every persisted walk has B(u) != B(v)."""
    task = rwnv_task(walks_per_vertex=1, length=8, seed=0)
    eng = BiBlockEngine(small_blocked, task)
    eng._initialize()
    starts = small_blocked.block_starts
    for b in range(small_blocked.num_blocks):
        batch, _wid = eng.pool.peek(b)
        if len(batch) == 0:
            continue
        bp = block_of(starts, batch.prev)
        bc = block_of(starts, batch.cur)
        assert np.all(bp != bc)
        np.testing.assert_array_equal(np.minimum(bp, bc), b)


def test_loader_switches_to_ondemand_late(small_blocked):
    """Paper §7.4 / Fig. 10: as walks drain, on-demand loading kicks in."""
    task = prnv_task(3, small_blocked.graph.num_vertices,
                     samples_per_vertex=2, seed=0)
    eng = BiBlockEngine(small_blocked, task, loading="auto")
    res = eng.run()
    assert res.stats.ondemand_ios > 0, "on-demand path never used"
    assert res.loader_summary["full_samples"] > 0


def test_weighted_graph_alias_sampling(tiny_graph):
    import numpy as np

    from repro.core import CSRGraph, partition_into_n_blocks

    g = tiny_graph
    rng = np.random.default_rng(0)
    w = (rng.random(g.num_edges) * 3 + 0.1).astype(np.float32)
    gw = CSRGraph(g.indptr, g.indices, w)
    bg = partition_into_n_blocks(gw, 3)
    task = deepwalk_task(walks_per_vertex=300, length=4, seed=0)
    res = InMemoryWalker(bg, task).run()
    # empirical first-step distribution from vertex 0 matches weights
    first = res.corpus[res.corpus[:, 0] == 0][:, 1]
    nb = g.neighbors(0)
    wv = gw.neighbor_weights(0)
    emp = np.array([(first == z).sum() for z in nb]) / len(first)
    np.testing.assert_allclose(emp, wv / wv.sum(), atol=0.06)
