"""The staged async bi-block pipeline: bit-identity, fault and gauge pins.

The async pipeline (walk-pool writer thread + next-slot pool drain/bucket
split preloads + plan-driven view prefetches) must be *observationally
identical* to the serial reference mode: same walks, same corpus, same
deterministic block/on-demand charges — across both pool backends, both
graph backends, and every pool shard count (the sharded pool partitions
the keyspace across per-shard sequenced writers; walk-spill charges are
additionally invariant across shard counts).  A writer-thread fault must
propagate out of ``run()``, remove any disk-pool spill directories, and
``close()`` must neither raise nor hang.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    IOStats,
    WalkBatch,
    deepwalk_task,
    erdos_renyi,
    partition_into_n_blocks,
    rwnv_task,
)
from repro.core.scheduler import TimeSlotPlan
from repro.engines.pipeline import BucketCursor
from repro.io import AsyncWalkPool, MemoryWalkPool
from repro.testing import given, settings, st


def _result_sig(res):
    return (
        res.endpoint_counts.tobytes(),
        None if res.corpus is None else res.corpus.tobytes(),
        res.stats.steps_sampled,
        res.stats.block_ios,
        res.stats.block_bytes,
        res.stats.ondemand_ios,
        res.stats.ondemand_bytes,
    )


# ---------------------------------------------------------------------------
# Property: async pipeline == serial reference, across the backend matrix
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nv=st.integers(60, 140),
    nblocks=st.integers(2, 5),
    flush=st.sampled_from([0, 16, 1 << 18]),
    shards=st.sampled_from([1, 2, 4]),
)
def test_async_pipeline_bitwise_identical_to_serial(seed, nv, nblocks, flush, shards):
    """async x {memory, disk} pool x {ram, disk} graph x pool_shards {1,2,4}
    == serial, bitwise, on random graphs — at spill-every-push, mid, and
    never-spill thresholds.  Every sharded run is compared to the same
    single-writer serial reference, so walks and block/on-demand charges
    are transitively bit-identical across shard counts too."""
    import shutil
    import tempfile

    from repro.io import DiskBlockedGraph, write_block_file

    g = erdos_renyi(nv, nv * 5, seed=seed)
    bg = partition_into_n_blocks(g, nblocks)
    tmp = tempfile.mkdtemp(prefix="grasorw_pipe_")
    try:
        path = os.path.join(tmp, f"g_{seed}_{nv}_{nblocks}.grb")
        write_block_file(bg, path)
        task = rwnv_task(p=3.0, q=0.5, walks_per_vertex=1, length=6, seed=seed)
        ref = _result_sig(
            BiBlockEngine(
                bg, task, record_walks=True, async_pipeline=False, pool_flush_walks=flush
            ).run()
        )
        for pool in ("memory", "disk"):
            for backend in ("ram", "disk"):
                bgx = bg if backend == "ram" else DiskBlockedGraph(path)
                res = BiBlockEngine(
                    bgx,
                    task,
                    record_walks=True,
                    async_pipeline=True,
                    pool=pool,
                    pool_flush_walks=flush,
                    pool_shards=shards,
                    pool_dir=os.path.join(tmp, f"pool_{pool}_{backend}_{shards}"),
                ).run()
                assert _result_sig(res) == ref, (
                    f"diverged at pool={pool} graph={backend} shards={shards}"
                )
                if backend == "disk":
                    bgx.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_async_pipeline_first_order_identical(small_blocked):
    task = deepwalk_task(walks_per_vertex=2, length=10, seed=3)
    r_serial = BiBlockEngine(
        small_blocked, task, record_walks=True, async_pipeline=False
    ).run()
    r_async = BiBlockEngine(small_blocked, task, record_walks=True).run()
    assert _result_sig(r_async) == _result_sig(r_serial)


def test_async_pipeline_overlaps_and_reduces_stalls(small_blocked):
    """The gauges: async overlaps load bytes and stalls strictly fewer slots
    than the serial run executes; both runs agree on the walks.  The gauges
    are deterministic (enqueue order, not thread timing) — pin that too."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    r_async = BiBlockEngine(small_blocked, task, pool_flush_walks=64).run()
    r_serial = BiBlockEngine(
        small_blocked, task, async_pipeline=False, pool_flush_walks=64
    ).run()
    np.testing.assert_array_equal(r_async.endpoint_counts, r_serial.endpoint_counts)
    assert r_async.stats.overlapped_load_bytes > 0
    assert r_async.stats.time_slots == r_serial.stats.time_slots
    assert r_async.stats.pipeline_stall_slots < r_serial.stats.time_slots
    # serial mode: every slot's pool load sat on the critical path
    assert r_serial.stats.pipeline_stall_slots == r_serial.stats.time_slots
    assert r_async.stats.writer_queue_peak > 0
    r_again = BiBlockEngine(small_blocked, task, pool_flush_walks=64).run()
    assert r_again.stats.overlapped_load_bytes == r_async.stats.overlapped_load_bytes
    assert r_again.stats.pipeline_stall_slots == r_async.stats.pipeline_stall_slots


def test_sharded_pool_charges_invariant_across_shard_counts(small_blocked):
    """Walk-spill charges are not merely deterministic per shard count —
    they are *invariant* across shard counts (a block's op stream lands on
    exactly one shard in program order, so its spill points cannot move),
    and the per-shard breakdown partitions the total exactly."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    ref = None
    for shards in (1, 2, 4, 8):
        res = BiBlockEngine(
            small_blocked, task, pool_flush_walks=64, pool_shards=shards
        ).run()
        s = res.stats
        sig = (
            res.endpoint_counts.tobytes(),
            s.walk_bytes_written,
            s.walk_bytes_read,
            s.block_ios,
            s.block_bytes,
            s.ondemand_ios,
            s.ondemand_bytes,
        )
        if ref is None:
            ref = sig
        assert sig == ref, f"diverged at pool_shards={shards}"
        if shards > 1:
            assert sum(s.shard_spill_bytes.values()) == s.walk_bytes_written
            assert len(s.shard_spill_bytes) >= 2, "spills never left one shard"


def test_writer_fault_leaves_no_orphaned_spill_dirs(small_blocked, tmp_path):
    """Satellite regression: a writer-thread fault aborting ``run()``
    mid-slot must remove the DiskWalkPool spill directories — including an
    explicitly-passed ``pool_dir`` the pool created (the whole makedirs
    chain, nested paths too) — not just the happy path's temp dir."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    for shards in (1, 4):
        # nested: every component below tmp_path is pool-created
        created_root = tmp_path / f"nested_{shards}"
        pool_dir = str(created_root / "deeper" / "pool")
        eng = BiBlockEngine(
            small_blocked,
            task,
            pool="disk",
            pool_flush_walks=0,
            pool_dir=pool_dir,
            pool_shards=shards,
        )
        assert os.path.isdir(pool_dir)

        def boom(b, batch, wid):
            raise RuntimeError("injected spill failure")

        if shards == 1:
            eng.pool.base._spill = boom
        else:
            for shard in eng.pool.shards:
                shard.base._spill = boom
        with pytest.raises(RuntimeError):
            eng.run()
        assert eng._closed
        assert not os.path.isdir(str(created_root)), (
            f"pool_shards={shards}: spill dir chain orphaned after a writer fault"
        )


# ---------------------------------------------------------------------------
# AsyncWalkPool: sequencing, tickets, faults, lifecycle
# ---------------------------------------------------------------------------

def _batch(rng, n, V=600):
    return WalkBatch(
        rng.integers(0, V, n), rng.integers(0, V, n),
        rng.integers(0, V, n), rng.integers(0, 100, n).astype(np.int32),
    )


def test_async_pool_preserves_serial_order_and_accounting():
    """Ticketed pushes + a FIFO drain reproduce the serial pool exactly:
    same walk order, same spill charges, prefix+remainder == one load."""
    rng = np.random.default_rng(0)
    batches = [_batch(rng, 7) for _ in range(6)]
    wids = [np.arange(7, dtype=np.int64) + 10 * k for k in range(6)]

    # push-order reference: one serial pool that sees all six pushes
    order_stats = IOStats()
    order_pool = MemoryWalkPool(2, order_stats, flush_walks=10)
    for b, w in zip(batches, wids):
        order_pool.push(0, b, w)
    ref_batch, ref_wid = order_pool.load(0)

    # accounting reference: a serial pool stepped through the SAME op
    # sequence the async pool will sequence (push x3, drain, push x3, drain)
    serial_stats = IOStats()
    serial = MemoryWalkPool(2, serial_stats, flush_walks=10)
    for b, w in zip(batches[:3], wids[:3]):
        serial.push(0, b, w)
    serial.load(0)
    for b, w in zip(batches[3:], wids[3:]):
        serial.push(0, b, w)
    serial.load(0)

    stats = IOStats()
    pool = AsyncWalkPool(MemoryWalkPool(2, stats, flush_walks=10), stats=stats)
    for b, w in zip(batches[:3], wids[:3]):
        pool.push(0, b, w)
    fut = pool.drain_async(0)  # prefix: exactly the first three pushes
    for b, w in zip(batches[3:], wids[3:]):
        pool.push(0, b, w)
    (pre_batch, pre_wid), n_pre, _spilled = fut.result()
    assert n_pre == 21
    rem_batch, rem_wid = pool.load(0)
    got = WalkBatch.concat([pre_batch, rem_batch])
    np.testing.assert_array_equal(got.cur, ref_batch.cur)
    np.testing.assert_array_equal(got.hop, ref_batch.hop)
    np.testing.assert_array_equal(np.concatenate([pre_wid, rem_wid]), ref_wid)
    # sequencing bookkeeping: every ticket applied, in order
    pool.barrier()
    assert pool.tickets_issued == 6 and pool.applied_ticket == 6
    assert pool.queue_peak >= 1 and stats.writer_queue_peak == pool.queue_peak
    # spill accounting matches the serial pool stepped through the same op
    # sequence (same thresholds crossed at the same points)
    assert stats.walk_bytes_written == serial_stats.walk_bytes_written
    assert stats.walk_bytes_read == serial_stats.walk_bytes_read
    pool.close()


def test_async_pool_eager_counts_match_sequential_view():
    stats = IOStats()
    pool = AsyncWalkPool(MemoryWalkPool(3, stats), stats=stats)
    rng = np.random.default_rng(1)
    pool.push(1, _batch(rng, 5), np.arange(5, dtype=np.int64))
    assert pool.counts[1] == 5  # visible before the writer applied it
    fut = pool.drain_async(1)
    assert pool.counts[1] == 0  # drained at the enqueue point
    pool.push(1, _batch(rng, 2), np.arange(2, dtype=np.int64))
    assert pool.counts[1] == 2  # post-drain pushes reaccumulate
    assert fut.result()[1] == 5
    pool.close()


def test_writer_fault_propagates_out_of_run_and_close_does_not_hang(small_blocked):
    """Satellite pin: an exception in the persist worker must propagate out
    of ``run()``, and the engine teardown must complete."""
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    # flush_walks=0 spills on every push, so the fault fires immediately
    eng = BiBlockEngine(small_blocked, task, pool_flush_walks=0)
    assert isinstance(eng.pool, AsyncWalkPool)

    def boom(b, batch, wid):
        raise RuntimeError("injected spill failure")

    eng.pool.base._spill = boom
    with pytest.raises(RuntimeError):
        eng.run()
    # run()'s finally already closed the engine; close again is idempotent
    # and must not hang on the dead writer
    t = threading.Thread(target=eng.close)
    t.start()
    t.join(timeout=30)
    assert not t.is_alive(), "close() hung after a writer fault"
    assert eng.pool._error is not None


def test_async_pool_operations_raise_after_fault():
    stats = IOStats()
    pool = AsyncWalkPool(MemoryWalkPool(2, stats, flush_walks=0), stats=stats)

    def boom(b, batch, wid):
        raise RuntimeError("boom")

    pool.base._spill = boom
    rng = np.random.default_rng(2)
    pool.push(0, _batch(rng, 3), np.arange(3, dtype=np.int64))
    with pytest.raises(RuntimeError):
        pool.barrier()
    with pytest.raises(RuntimeError):
        pool.push(0, _batch(rng, 3), np.arange(3, dtype=np.int64))
    pool.close()
    pool.close()  # idempotent


# ---------------------------------------------------------------------------
# TimeSlotPlan / BucketCursor mechanics
# ---------------------------------------------------------------------------

def test_time_slot_plan_orders():
    p2 = TimeSlotPlan(6, order=2)
    assert list(p2.slots()) == [0, 1, 2, 3, 4]  # last block never owns a pool
    assert list(p2.ancillary_after(2)) == [3, 4, 5]
    p1 = TimeSlotPlan(6, order=1)
    assert list(p1.slots()) == [0, 1, 2, 3, 4, 5]


def test_time_slot_plan_next_slot_wraps():
    plan = TimeSlotPlan(5, order=2)  # slots 0..3
    pending = {2}
    assert plan.next_slot(0, lambda b: b in pending) == 2
    assert plan.next_slot(2, lambda b: b in pending) == 2  # wraps to itself
    assert plan.next_slot(3, lambda b: b in pending) == 2  # next superstep
    assert plan.next_slot(0, lambda b: False) is None


def test_bucket_cursor_matches_sorted_rescan_with_extensions():
    """The ordered cursor pops what ``sorted(pending)`` would, including
    ids merged in mid-iteration (buckets only grow, targets only later)."""
    rng = np.random.default_rng(3)
    cur = BucketCursor()
    for i in (4, 2, 7):
        cur.add(i, _batch(rng, 2), np.arange(2, dtype=np.int64))
    assert len(cur) == 3 and 4 in cur
    i1, b1, w1 = cur.pop()
    assert i1 == 2 and cur.peek() == 4
    # extension grows an existing bucket and creates a new later one
    cur.add(4, _batch(rng, 3), np.arange(3, dtype=np.int64))
    cur.add(5, _batch(rng, 1), np.zeros(1, np.int64))
    i2, b2, w2 = cur.pop()
    assert i2 == 4 and len(b2) == 5  # merged in push order
    assert [cur.pop()[0], cur.pop()[0]] == [5, 7]
    assert cur.pop() is None and cur.peek() is None
