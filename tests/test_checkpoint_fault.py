"""Checkpoint atomicity/restore + fault-tolerant trainer (crash -> resume)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import reduced_config
from repro.data import WalkCorpus
from repro.models import model_init
from repro.optim import OptConfig, adamw_init
from repro.runtime import FailureInjector, ResilientTrainer, StragglerWatchdog
from repro.train import make_train_step


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t, extra={"cursor": 42})
    assert latest_step(tmp_path) == 3
    got, extra = restore_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a crash mid-write: dir exists but no manifest
    (tmp_path / "step_000000009").mkdir()
    assert latest_step(tmp_path) == 1


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_straggler_watchdog_fires():
    w = StragglerWatchdog(factor=3.0, warmup=2)
    for i in range(6):
        assert not w.observe(i, 0.1)
    assert w.observe(6, 1.0)  # 10x the EMA
    assert len(w.stragglers) == 1


def _setup_trainer(tmp_path, fail_at=()):
    cfg = reduced_config("llama3.2-1b")
    rng = np.random.default_rng(0)
    walks = rng.integers(0, 200, (64, 17)).astype(np.int32)
    corpus = WalkCorpus.from_walks(walks, 200)
    params = model_init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, total_steps=100)))
    trainer = ResilientTrainer(
        train_step=step,
        ckpt_dir=tmp_path / "ckpt",
        ckpt_every=4,
        injector=FailureInjector(fail_at),
    )
    return cfg, corpus, params, opt, trainer


def test_crash_restart_resumes_deterministically(tmp_path):
    """Train 12 steps with a crash at step 9 + restart == uninterrupted run."""
    cfg, corpus, params0, opt0, trainer = _setup_trainer(tmp_path / "x")

    def batches(cursor=0):
        return corpus.batches(4, 16, cursor=cursor, epochs=None, seed=7)

    # uninterrupted reference
    p_ref, _, info = trainer.run(params0, opt0, batches(), num_steps=12)

    # crashing run
    cfg2, corpus2, params1, opt1, trainer2 = _setup_trainer(
        tmp_path / "y", fail_at=(9,)
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        trainer2.run(params1, opt1, batches(), num_steps=12)
    # restart: restore the latest COMMITTED checkpoint.  The async save at
    # step 8 races the crash at step 9 — losing it is correct semantics
    # (an uncommitted checkpoint never existed); what must hold is that the
    # resumed run reproduces the reference exactly from ANY committed step.
    restored = trainer2.resume(
        {"params": params1, "opt_state": opt1}["params"], opt1
    )
    assert restored is not None
    params_r, opt_r, start, cursor = restored
    assert start in (4, 8)
    trainer2.injector = None
    p_done, _, _ = trainer2.run(
        params_r, opt_r, batches(cursor), num_steps=12, start_step=start
    )
    for a, b in zip(jax.tree.leaves(p_done), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure (jax.sharding.AxisType missing on the "
    "pinned jax); ROADMAP: 'Fix 3 pre-existing failures'",
)
def test_elastic_restore_resharding(tmp_path):
    """Restore re-device_puts against new shardings (mesh change path)."""
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(tmp_path, 1, t)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = restore_checkpoint(tmp_path, t, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
