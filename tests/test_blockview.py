"""The BlockView layer: activated-subgraph execution correctness pins.

The strongest property in the system: walks are a pure function of
``(task seed, walk id)`` — independent of the loading method, graph backend,
walk-pool backend, bucket scheduling, and even of whether the whole graph is
resident (the in-memory oracle).  These tests pin it, plus the footprint
win (``peak_resident_bytes``) and the engine lifecycle fixes (close on
raise, idempotent close, uniform ``loader_summary``).
"""

import os

import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    BlockView,
    CSRGraph,
    InMemoryWalker,
    PlainBucketEngine,
    erdos_renyi,
    partition_into_n_blocks,
    rwnv_task,
)
from repro.core.transition import Node2vec, WalkTask
from repro.testing import given, settings, st


def _result_sig(res):
    return (
        res.endpoint_counts.tobytes(),
        None if res.corpus is None else res.corpus.tobytes(),
        res.stats.steps_sampled,
    )


# ---------------------------------------------------------------------------
# Property: loading methods and backends never change the walks
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    nv=st.integers(60, 140),
    nblocks=st.integers(2, 5),
    weighted=st.booleans(),
)
def test_loading_modes_bit_identical(seed, nv, nblocks, weighted):
    """full / ondemand / auto x ram / disk graph: identical endpoint
    histograms and corpora on random small graphs."""
    import tempfile

    from repro.io import DiskBlockedGraph, write_block_file

    g = erdos_renyi(nv, nv * 5, seed=seed)
    if weighted:
        rng = np.random.default_rng(seed)
        g = CSRGraph(
            g.indptr, g.indices,
            (rng.random(g.num_edges) * 2 + 0.25).astype(np.float32),
        )
    bg = partition_into_n_blocks(g, nblocks)
    path = os.path.join(
        tempfile.mkdtemp(prefix="grasorw_bv_"),
        f"g_{seed}_{nv}_{nblocks}_{int(weighted)}.grb",
    )
    write_block_file(bg, path)
    task = rwnv_task(p=3.0, q=0.5, walks_per_vertex=1, length=6, seed=seed)
    ref = None
    for loading in ("full", "ondemand", "auto"):
        for backend in ("ram", "disk"):
            bgx = bg if backend == "ram" else DiskBlockedGraph(path)
            res = BiBlockEngine(bgx, task, loading=loading, record_walks=True).run()
            sig = _result_sig(res)
            if ref is None:
                ref = sig
            assert sig == ref, f"diverged at loading={loading} graph={backend}"
            if backend == "disk":
                bgx.close()
    os.remove(path)


def test_engines_match_inmemory_oracle_bitwise(small_blocked):
    """Counter-based RNG: out-of-core engines sample the *same walks* as the
    whole-graph oracle, not merely the same distribution.  SOGW/SGSC
    qualify too since their paid-for prev adjacencies are pinned as a
    gathered view (the membership probe runs on the true rows)."""
    from repro.core import SOGWEngine

    task = rwnv_task(p=2.0, q=0.5, walks_per_vertex=2, length=10, seed=11)
    oracle = InMemoryWalker(small_blocked, task).run()
    engines = [
        BiBlockEngine(small_blocked, task, record_walks=True),
        PlainBucketEngine(small_blocked, task, record_walks=True),
        SOGWEngine(small_blocked, task, record_walks=True),
        SOGWEngine(small_blocked, task, static_cache=True, record_walks=True),
    ]
    for eng in engines:
        res = eng.run()
        np.testing.assert_array_equal(res.endpoint_counts, oracle.endpoint_counts)
        np.testing.assert_array_equal(res.corpus, oracle.corpus)
        assert res.stats.steps_sampled == oracle.stats.steps_sampled


def test_ondemand_restart_task_identical(small_blocked):
    """Decay termination draws are (walk, hop)-keyed too."""
    task = WalkTask(
        Node2vec(p=2.0, q=0.5), length=15,
        query_vertex=3, total_walks=256, decay=0.85, seed=4,
    )
    r_full = BiBlockEngine(small_blocked, task, loading="full").run()
    r_od = BiBlockEngine(small_blocked, task, loading="ondemand").run()
    np.testing.assert_array_equal(r_full.endpoint_counts, r_od.endpoint_counts)
    assert r_od.stats.ondemand_ios > 0


# ---------------------------------------------------------------------------
# The footprint win and the view mechanics
# ---------------------------------------------------------------------------

def test_ondemand_peak_resident_bytes_lower():
    """Sparse buckets on a skewed graph: activated views shrink the resident
    peak (the bench's ondemand_exec acceptance, at test scale)."""
    from repro.core import barabasi_albert

    g = barabasi_albert(1500, 8, seed=3)
    bg = partition_into_n_blocks(g, 8)
    task = WalkTask(
        Node2vec(p=2.0, q=0.5), length=20,
        query_vertex=5, total_walks=256, decay=0.85, seed=9,
    )
    r_full = BiBlockEngine(bg, task, loading="full").run()
    r_od = BiBlockEngine(bg, task, loading="ondemand").run()
    np.testing.assert_array_equal(r_full.endpoint_counts, r_od.endpoint_counts)
    assert 0 < r_od.stats.peak_resident_bytes < r_full.stats.peak_resident_bytes


def test_partial_view_rows_match_full(small_blocked):
    """An activated view's rows are bit-identical to the full block's."""
    b = 1
    full = BlockView.from_resident(small_blocked.materialize_block(b))
    s = int(small_blocked.block_starts[b])
    rng = np.random.default_rng(0)
    verts = rng.choice(
        np.arange(s, int(small_blocked.block_starts[b + 1])), 17, replace=False
    )
    part = small_blocked.partial_view(b, verts)
    assert part.kind == "activated" and full.kind == "full"
    np.testing.assert_array_equal(part.vids, np.unique(verts))
    for k, v in enumerate(part.vids):
        np.testing.assert_array_equal(part.row(k), full.row(int(v) - s))
    assert part.nbytes() < full.nbytes()


def test_view_extension_appends_rows(small_blocked):
    b = 0
    s, e = int(small_blocked.block_starts[b]), int(small_blocked.block_starts[b + 1])
    base = small_blocked.partial_view(b, np.arange(s, s + 5))
    ext = small_blocked.partial_view(b, np.arange(s + 8, s + 11))
    merged = base.extended(ext)
    assert merged.nverts == 8
    np.testing.assert_array_equal(
        merged.vids, np.concatenate([np.arange(s, s + 5), np.arange(s + 8, s + 11)])
    )
    full = BlockView.from_resident(small_blocked.materialize_block(b))
    for k, v in enumerate(merged.vids):
        np.testing.assert_array_equal(merged.row(k), full.row(int(v) - s))
    with pytest.raises(ValueError):
        merged.extended(small_blocked.partial_view(b + 1, np.arange(e, e + 2)))


def test_blockstore_partial_prefetch_subset_served(small_blocked):
    """A prefetched partial view is served as a base when the request grew
    (buckets only gain walks) and never changes the served vertex set."""
    from repro.core import IOStats
    from repro.io import BlockStore

    stats = IOStats()
    store = BlockStore(small_blocked, stats)
    s = int(small_blocked.block_starts[2])
    store.prefetch_partial(2, np.arange(s, s + 6))
    view = store.partial_view(2, np.arange(s, s + 10))  # grew by 4
    assert store.partial_prefetch_hits == 1
    np.testing.assert_array_equal(view.vids, np.arange(s, s + 10))
    # a non-subset prefetch is discarded, never served
    store.prefetch_partial(2, np.arange(s + 20, s + 24))
    view2 = store.partial_view(2, np.arange(s, s + 3))
    assert store.partial_builds == 1
    np.testing.assert_array_equal(view2.vids, np.arange(s, s + 3))
    store.close()


# ---------------------------------------------------------------------------
# Engine lifecycle: close on raise, idempotent close, uniform loader_summary
# ---------------------------------------------------------------------------

def test_run_closes_storage_on_raise(small_blocked, tmp_path, monkeypatch):
    """A run that raises still releases the prefetch thread and the disk
    pool's spill dir (regression: close() was skipped when run() raised)."""
    task = rwnv_task(walks_per_vertex=1, length=8, seed=0)
    eng = BiBlockEngine(
        small_blocked, task, pool="disk", pool_flush_walks=0,
    )
    pool_dir = eng.pool.directory
    assert os.path.isdir(pool_dir)

    def boom(*a, **kw):
        raise RuntimeError("injected advance failure")

    monkeypatch.setattr(eng, "_advance", boom)
    with pytest.raises(RuntimeError, match="injected"):
        eng.run()
    assert eng._closed
    assert not os.path.isdir(pool_dir), "disk pool spill dir leaked"
    assert eng.blocks._executor is None, "prefetch executor leaked"
    # close() is idempotent — result() after run() double-closes safely
    eng.close()
    eng.close()


def test_engine_context_manager(small_blocked):
    task = rwnv_task(walks_per_vertex=1, length=6, seed=0)
    with BiBlockEngine(small_blocked, task) as eng:
        res = eng.run()
    assert eng._closed
    assert res.endpoint_counts.sum() == res.num_walks


def test_loader_summary_uniform_across_engines(small_blocked):
    """result() reports loader_summary uniformly: a dict for the LBL engine,
    None for baselines and the oracle — never a missing attribute."""
    from repro.core import SOGWEngine

    task = rwnv_task(walks_per_vertex=1, length=6, seed=0)
    r_bb = BiBlockEngine(small_blocked, task).run()
    assert isinstance(r_bb.loader_summary, dict)
    assert "full_samples" in r_bb.loader_summary
    for Engine in (PlainBucketEngine, SOGWEngine):
        res = Engine(small_blocked, task).run()
        assert res.loader_summary is None
    assert InMemoryWalker(small_blocked, task).run().loader_summary is None
