"""Block-level references: MoE vs dense per-token loop, SSD vs naive
recurrence, RG-LRU scan vs sequential loop."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models.common import ModelConfig
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_apply, rglru_decode, rglru_init, init_rglru_cache
from repro.models.ssm import init_ssd_cache, ssd_apply, ssd_decode, ssd_init


def _moe_dense_reference(params, x, cfg):
    """Per-token dense evaluation of the same routed experts."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    w_in = params["experts"]["w_in"]
    w_out = params["experts"]["w_out"]
    out = jnp.zeros((T, D))
    for kk in range(cfg.top_k):
        e = idx[:, kk]
        h = jnp.einsum("td,tdf->tf", xt, w_in[e])
        g, u = jnp.split(h, 2, -1)
        y = jnp.einsum("tf,tfd->td", jax.nn.silu(g) * u, w_out[e])
        out = out + gate[:, kk:kk + 1] * y
    if "shared" in params:
        h = xt @ params["shared"]["w_in"]
        g, u = jnp.split(h, 2, -1)
        out = out + (jax.nn.silu(g) * u) @ params["shared"]["w_out"]
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference():
    cfg = reduced_config("deepseek-v2-236b")
    # ample capacity so nothing drops
    cfg = ModelConfig(**{**cfg.__dict__, "capacity_factor": 8.0})
    params = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)).astype(np.float32))
    got, aux = moe_apply(params, x, cfg)
    want = _moe_dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_gracefully():
    cfg = reduced_config("mixtral-8x22b")
    cfg = ModelConfig(**{**cfg.__dict__, "capacity_factor": 0.25})
    params = moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.ones((1, 8, cfg.d_model), jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def _ssd_naive(params, x, cfg):
    """Literal per-step SSM recurrence (the definition SSD must equal)."""
    out = []
    cache = init_ssd_cache(cfg, x.shape[0])
    for t in range(x.shape[1]):
        y, cache = ssd_decode(params, x[:, t:t + 1], cache, cfg)
        out.append(y)
    return jnp.concatenate(out, 1)


def test_ssd_matches_naive_recurrence():
    cfg = reduced_config("mamba2-2.7b")
    params = ssd_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)).astype(np.float32))
    got, _ = ssd_apply(params, x, cfg, chunk=4)
    want = _ssd_naive(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-3)


def test_ssd_chunk_invariance():
    cfg = reduced_config("mamba2-2.7b")
    params = ssd_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)).astype(np.float32))
    y4, _ = ssd_apply(params, x, cfg, chunk=4)
    y8, _ = ssd_apply(params, x, cfg, chunk=8)
    y16, _ = ssd_apply(params, x, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8), atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), atol=2e-4, rtol=2e-3)


def test_rglru_scan_matches_stepwise():
    cfg = reduced_config("recurrentgemma-2b")
    params = rglru_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 10, cfg.d_model)).astype(np.float32))
    got, final = rglru_apply(params, x, cfg)
    cache = init_rglru_cache(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        y, cache = rglru_decode(params, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    want = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-4)
    np.testing.assert_allclose(np.asarray(final["h"]), np.asarray(cache["h"]),
                               atol=3e-5, rtol=3e-4)
