"""The serving front end: admission batching, hot-set pinning, and the
CRC-identity contract with direct batch runs (docs/serving.md)."""

import numpy as np
import pytest

from repro.core import barabasi_albert, partition_into_n_blocks
from repro.core.stats import IOStats
from repro.engines.biblock import BiBlockEngine
from repro.io import BlockStore
from repro.serve import (
    AdmissionQueue,
    HotSetPolicy,
    QueryConfig,
    WalkQuery,
    WalkQueryServer,
)

CFG = QueryConfig(p=1.0, q=2.0, length=6, decay=0.85, samples=8)


@pytest.fixture(scope="module")
def bg():
    return partition_into_n_blocks(barabasi_albert(400, 5, seed=3), 5)


def _skewed_sources(bg, n, frac=0.8, seed=7):
    rng = np.random.default_rng(seed)
    hi = int(bg.block_starts[1])
    return np.where(
        rng.random(n) < frac,
        rng.integers(0, hi, n),
        rng.integers(0, bg.num_vertices, n),
    ).astype(np.int64)


def _serve(bg, sources, cfg=CFG, **kw):
    kw.setdefault("async_pipeline", False)
    server = WalkQueryServer(bg, seed=11, **kw)
    with server:
        for s in sources:
            server.submit(int(s), cfg)
        return server, server.flush()


# -- the CRC-identity contract -------------------------------------------------
def test_served_batches_match_direct_runs(bg):
    sources = _skewed_sources(bg, 12)
    server, answers = _serve(bg, sources, max_batch=8)
    assert server.batches_served == 2
    for k, lo in enumerate((0, 8)):
        batch = answers[lo : lo + 8]
        served = np.zeros(bg.num_vertices, np.int64)
        for a in batch:
            served += a.dense_counts(bg.num_vertices)
        direct = BiBlockEngine(
            bg,
            CFG.task(server.batch_seed(k)),
            initial_walks=np.repeat([a.source for a in batch], CFG.samples),
            async_pipeline=False,
        ).run()
        assert np.array_equal(served, direct.endpoint_counts)


def test_pinning_never_changes_answers_and_saves_block_loads(bg):
    sources = _skewed_sources(bg, 24)
    hot, hot_ans = _serve(bg, sources, max_batch=8, hot_blocks=2)
    lru, lru_ans = _serve(bg, sources, max_batch=8, hot_blocks=0)
    for a, b in zip(hot_ans, lru_ans):
        assert np.array_equal(a.vertices, b.vertices)
        assert np.array_equal(a.counts, b.counts)
    assert hot.stats.pinned_block_hits > 0
    assert hot.stats.pinned_bytes_saved > 0
    assert hot.stats.block_ios < lru.stats.block_ios
    assert lru.stats.pinned_block_hits == 0


def test_per_query_attribution_and_latency(bg):
    sources = _skewed_sources(bg, 6)
    server, answers = _serve(bg, sources)
    assert [a.qid for a in answers] == list(range(6))
    for a, s in zip(answers, sources):
        assert a.source == int(s)
        assert int(a.counts.sum()) == CFG.samples  # every walk terminated once
        assert a.latency > 0.0
        verts, probs = a.ppr()
        assert np.isclose(probs.sum(), 1.0) and np.all(verts[:-1] < verts[1:])
    summary = server.latency_summary()
    assert summary["answered"] == 6
    assert summary["p50"] <= summary["p95"] <= summary["p99"]
    assert server.answer(0) is answers[0] and server.answer(99) is None


# -- admission batching --------------------------------------------------------
def test_admission_groups_by_config_oldest_head_first():
    q = AdmissionQueue(max_batch=2)
    cfg_a, cfg_b = QueryConfig(q=2.0), QueryConfig(q=4.0)
    for qid, cfg in enumerate([cfg_b, cfg_a, cfg_b, cfg_a, cfg_b]):
        q.submit(WalkQuery(qid, source=qid, config=cfg, t_submit=0.0))
    assert len(q) == 5
    # oldest pending head is qid 0 (cfg_b); FIFO within the group
    cfg, batch = q.pop_batch()
    assert cfg == cfg_b and [w.qid for w in batch] == [0, 2]
    cfg, batch = q.pop_batch()
    assert cfg == cfg_a and [w.qid for w in batch] == [1, 3]
    cfg, batch = q.pop_batch()
    assert cfg == cfg_b and [w.qid for w in batch] == [4]
    assert q.pop_batch() is None and len(q) == 0


def test_admission_rejects_bad_max_batch():
    with pytest.raises(ValueError):
        AdmissionQueue(max_batch=0)


# -- the hot-set policy --------------------------------------------------------
def test_hot_set_policy_top_blocks_ties_and_thresholds():
    p = HotSetPolicy(6, max_pinned=2, min_arrivals=2)
    assert p.hot_set().size == 0  # nothing qualifies yet
    for b, n in ((4, 3), (1, 3), (2, 1)):
        p.observe(b, n)
    # 1 and 4 tie the lead -> both in; 2 is below min_arrivals
    assert p.hot_set().tolist() == [1, 4]
    p.observe(2, 5)
    assert p.hot_set().tolist() == [1, 2] or p.hot_set().tolist() == [2, 1]
    assert HotSetPolicy(6, max_pinned=0).hot_set().size == 0
    with pytest.raises(ValueError):
        HotSetPolicy(6, max_pinned=-1)


# -- BlockStore pinning units --------------------------------------------------
def test_pinned_block_charges_once_then_serves_free(bg):
    stats = IOStats()
    store = BlockStore(bg, stats, enable_prefetch=False, capacity=2)
    store.pin([0])
    assert store.pinned() == frozenset({0})
    assert stats.hot_pinned_blocks == 1
    store.get(0)  # first touch: one normal charge
    assert stats.block_ios == 1 and stats.pinned_block_hits == 0
    store.get(0)
    store.get(0)
    assert stats.block_ios == 1  # no further block_load charges
    assert stats.pinned_block_hits == 2 and stats.pinned_bytes_saved > 0
    store.close()


def test_pinned_blocks_are_exempt_from_lru_eviction(bg):
    stats = IOStats()
    store = BlockStore(bg, stats, enable_prefetch=False, capacity=2)
    store.pin([0])
    store.get(0)
    for b in (1, 2, 3, 4):  # churn far past the LRU capacity
        store.get(b)
    ios = stats.block_ios
    store.get(0)  # still resident: pinned, never evicted
    assert stats.block_ios == ios
    store.unpin([0])
    assert store.pinned() == frozenset()
    for b in (1, 2, 3, 4):
        store.get(b)
    store.get(0)  # unpinned copy has aged out of the small LRU by now
    assert stats.block_ios > ios
    store.close()


def test_set_pinned_reconciles_and_promotes_resident_copies(bg):
    stats = IOStats()
    store = BlockStore(bg, stats, enable_prefetch=False, capacity=2)
    store.get(1)  # LRU-resident; pinning must promote, not re-load
    store.set_pinned([1, 2])
    assert store.pinned() == frozenset({1, 2})
    ios = stats.block_ios
    store.get(1)
    assert stats.block_ios == ios and stats.pinned_block_hits == 1
    store.set_pinned([2])
    assert store.pinned() == frozenset({2})
    assert stats.hot_pinned_blocks == 1
    assert store.counters()["pinned_blocks"] == 1
    store.close()


def test_shared_store_requires_matching_stats(bg):
    stats = IOStats()
    store = BlockStore(bg, stats, enable_prefetch=False, capacity=2)
    with pytest.raises(ValueError):
        BiBlockEngine(bg, CFG.task(0), block_store=store, stats=IOStats())
    store.close()


def test_submit_rejects_out_of_range_source(bg):
    with WalkQueryServer(bg, async_pipeline=False) as server:
        with pytest.raises(ValueError):
            server.submit(bg.num_vertices)
