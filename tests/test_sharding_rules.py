"""Sharding rules must produce divisible specs for every arch x mesh —
this is the CPU-cheap version of the dry-run's guarantee."""

import numpy as np
import pytest
import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.models import init_params_shape, model_caches
from repro.sharding import batch_specs, cache_specs, param_specs


class FakeMesh:
    """Shape-only stand-in (constructing 256 fake devices is not needed to
    check divisibility)."""

    def __init__(self, shape):
        self.shape = dict(shape)


MESHES = [
    FakeMesh({"data": 16, "model": 16}),
    FakeMesh({"pod": 2, "data": 16, "model": 16}),
]


def _check_divisible(specs, shapes, mesh, where):
    from jax.sharding import PartitionSpec

    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec)
    )[0]
    flat_l = jax.tree.leaves(shapes)
    assert len(flat_s) == len(flat_l)
    for (path, spec), leaf in zip(flat_s, flat_l):
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, (
                f"{where}: {path} dim {dim} size {leaf.shape[dim]} "
                f"not divisible by {n}"
            )


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = init_params_shape(cfg)
    specs = param_specs(cfg, shapes, mesh)
    _check_divisible(specs, shapes, mesh, f"{arch} params")


@pytest.mark.parametrize("arch", ["yi-34b", "mamba2-2.7b", "recurrentgemma-2b",
                                  "deepseek-v2-236b", "whisper-tiny"])
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, mesh, shape):
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        pytest.skip("shape inapplicable")
    spec = SHAPES[shape]
    caches = jax.eval_shape(
        lambda: model_caches(cfg, spec.global_batch, spec.seq_len,
                             enc_len=spec.seq_len)
    )
    specs = cache_specs(cfg, caches, mesh, spec.global_batch)
    _check_divisible(specs, caches, mesh, f"{arch} caches {shape}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_all_shapes(arch):
    cfg = get_config(arch)
    for mesh in MESHES:
        for name, spec in SHAPES.items():
            if not shape_applicable(cfg, name):
                continue
            out = batch_specs(cfg, mesh, spec.global_batch, kind=spec.kind)
            assert "tokens" in out or "token" in out
            # batch=1 (long_500k) must not be sharded
            if spec.global_batch == 1:
                for s in out.values():
                    assert len(s) == 0 or s[0] is None


def test_param_count_sanity():
    """Full configs land near their advertised sizes."""
    expected = {
        "qwen1.5-0.5b": (0.4e9, 0.8e9),
        "llama3.2-1b": (1.0e9, 1.5e9),
        "phi3-mini-3.8b": (3.0e9, 4.5e9),
        "yi-34b": (30e9, 38e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "mixtral-8x22b": (120e9, 150e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "recurrentgemma-2b": (2.2e9, 3.4e9),
        "internvl2-1b": (0.5e9, 1.2e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("mixtral-8x22b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < 0.45 * total  # top-2 of 8 experts + attention
    ds = get_config("deepseek-v2-236b")
    assert ds.active_param_count() < 0.2 * ds.param_count()
