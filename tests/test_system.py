"""End-to-end system tests: the paper's pipeline feeding the LM stack.

walk generation (GraSorw engine) -> corpus -> LM training (llama-family
reduced config) with checkpointing; plus PPR-query agreement between the
out-of-core engine and the in-memory oracle.
"""

import numpy as np
import jax

from repro.configs import reduced_config
from repro.core import (
    BiBlockEngine,
    InMemoryWalker,
    partition_into_n_blocks,
    prnv_task,
    rwnv_task,
)
from repro.data import WalkCorpus
from repro.models import model_init
from repro.optim import OptConfig, adamw_init
from repro.train import make_train_step


def test_walks_to_lm_training():
    from repro.core import erdos_renyi

    g = erdos_renyi(400, 3200, seed=9)  # vocab must fit the reduced config
    bg = partition_into_n_blocks(g, 4)
    task = rwnv_task(walks_per_vertex=2, length=20, seed=0)
    res = BiBlockEngine(bg, task, record_walks=True).run()
    corpus = WalkCorpus.from_walks(res.corpus, g.num_vertices)

    cfg = reduced_config("llama3.2-1b")
    assert corpus.vocab_size <= cfg.vocab_size
    params = model_init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=2,
                                                  total_steps=40)))
    losses = []
    for i, batch in enumerate(corpus.batches(8, 24, epochs=None, seed=0)):
        batch.pop("cursor"), batch.pop("epoch")
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i >= 14:
            break
    assert np.isfinite(losses).all()
    assert min(losses[-3:]) < losses[0], f"no learning: {losses}"


def test_ppr_engine_agrees_with_oracle(small_blocked):
    """PRNV endpoint distribution: out-of-core engine vs in-memory oracle."""
    g = small_blocked.graph
    task = prnv_task(11, g.num_vertices, samples_per_vertex=16, seed=5)
    r_engine = BiBlockEngine(small_blocked, task).run()
    r_oracle = InMemoryWalker(small_blocked, task).run(record_walks=False)
    p1 = r_engine.ppr_estimate()
    p2 = r_oracle.ppr_estimate()
    # two Monte-Carlo estimates with different rng: TV ~ O(sqrt(K/n))
    tv = 0.5 * np.abs(p1 - p2).sum()
    assert tv < 0.2, f"total variation {tv:.3f} too high"
    top1 = set(np.argsort(-p1)[:20])
    top2 = set(np.argsort(-p2)[:20])
    assert len(top1 & top2) >= 10


def test_walk_corpus_full_coverage(small_blocked):
    """RWNV starts 10 walks/vertex (paper setting scaled): every vertex is a
    source and every recorded step is a real edge."""
    g = small_blocked.graph
    task = rwnv_task(walks_per_vertex=1, length=6, seed=2)
    res = BiBlockEngine(small_blocked, task, record_walks=True).run()
    srcs = res.corpus[:, 0]
    np.testing.assert_array_equal(np.sort(srcs), np.arange(g.num_vertices))
    rng = np.random.default_rng(0)
    for i in rng.integers(0, len(res.corpus), 60):
        row = res.corpus[i]
        row = row[row >= 0]
        for t in range(len(row) - 1):
            assert row[t + 1] in g.neighbors(row[t]), "non-edge step recorded"
