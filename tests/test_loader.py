"""Learning-based block loading model (paper §5)."""

import numpy as np

from repro.core import BlockLoadingModel, LinearCostModel


def test_linear_fit_recovers_coefficients():
    rng = np.random.default_rng(0)
    m = LinearCostModel(with_intercept=True)
    a, b = 3.5, 0.8
    for _ in range(200):
        x = rng.random()
        m.add(x, a * x + b + rng.normal(0, 1e-3))
    af, bf = m.fit()
    assert abs(af - a) < 0.01 and abs(bf - b) < 0.01

    m0 = LinearCostModel(with_intercept=False)
    for _ in range(200):
        x = rng.random()
        m0.add(x, 2.0 * x + rng.normal(0, 1e-3))
    a0, b0 = m0.fit()
    assert abs(a0 - 2.0) < 0.02 and b0 == 0.0


def test_eta0_threshold_and_choice():
    """Synthetic costs with known crossover eta0 = b_f/(a_o-a_f) (Eq. 5)."""
    model = BlockLoadingModel(num_blocks=2, mode="auto", min_samples=3)
    a_f, b_f, a_o = 1.0, 0.10, 3.0  # eta0 = 0.05
    for eta in np.linspace(0.01, 0.5, 20):
        model.observe(0, float(eta), a_f * eta + b_f, "full")
        model.observe(0, float(eta), a_o * eta, "ondemand")
    eta0 = model.eta0(0)
    assert abs(eta0 - 0.05) < 0.005
    nv = 1000
    assert model.choose(0, int(0.2 * nv), nv) == "full"  # eta 0.2 > 0.05
    assert model.choose(0, int(0.01 * nv), nv) == "ondemand"


def test_forced_modes():
    m = BlockLoadingModel(3, mode="train_full")
    assert m.choose(0, 1, 100) == "full"
    m = BlockLoadingModel(3, mode="train_ondemand")
    assert m.choose(0, 99, 100) == "ondemand"


def test_global_fallback_used_before_block_samples():
    model = BlockLoadingModel(num_blocks=4, mode="auto", min_samples=2)
    for eta in (0.1, 0.2, 0.3):
        model.observe(1, eta, 1.0 * eta + 0.05, "full")
        model.observe(1, eta, 2.0 * eta, "ondemand")
    # block 3 has no samples; global model should drive the threshold
    assert abs(model.eta0(3) - 0.05) < 0.01
