"""Storage layer: WalkPool backends (memory/disk) and the BlockStore.

Pins the PR's acceptance criteria: disk pools write real 16-byte packed
records whose on-disk size matches the walk-byte accounting; engines are
bit-identical across pool backends at a fixed seed; ``pool_flush_walks`` is
the spill threshold; a prefetched block is served without a second
``block_load`` charge.
"""


import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    IOStats,
    PlainBucketEngine,
    SOGWEngine,
    WalkBatch,
    pack_walks,
    rwnv_task,
)
from repro.io import BlockStore, DiskWalkPool, MemoryWalkPool, make_walk_pool


def _random_batch(rng, n, V):
    return WalkBatch(
        rng.integers(0, V, n), rng.integers(0, V, n),
        rng.integers(0, V, n), rng.integers(0, 100, n).astype(np.int32),
    )


STARTS = np.array([0, 100, 250, 400, 600])


# ---------------------------------------------------------------------------
# DiskWalkPool <-> pack_walks round trip
# ---------------------------------------------------------------------------

def test_disk_pool_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    stats = IOStats()
    pool = DiskWalkPool(4, stats, STARTS, flush_walks=8, directory=str(tmp_path))
    pushed, wids = [], []
    for k in range(5):
        batch = _random_batch(rng, 7, 600)
        wid = np.arange(7, dtype=np.int64) + 100 * k
        pool.push(2, batch, wid)
        pushed.append(batch)
        wids.append(wid)
    out, wid_out = pool.load(2)
    ref = WalkBatch.concat(pushed)
    np.testing.assert_array_equal(out.src, ref.src)
    np.testing.assert_array_equal(out.prev, ref.prev)
    np.testing.assert_array_equal(out.cur, ref.cur)
    np.testing.assert_array_equal(out.hop, ref.hop)
    np.testing.assert_array_equal(wid_out, np.concatenate(wids))
    assert pool.counts[2] == 0
    # the records on disk were the real 16-byte packed encoding
    assert stats.walk_bytes_written == pool.bytes_written
    assert pool.bytes_written % 16 == 0


def test_disk_pool_on_disk_bytes_match_accounting(tmp_path):
    rng = np.random.default_rng(1)
    stats = IOStats()
    pool = DiskWalkPool(4, stats, STARTS, flush_walks=0, directory=str(tmp_path))
    total = 0
    for b in (0, 1, 3):
        n = int(rng.integers(5, 40))
        pool.push(b, _random_batch(rng, n, 600), np.arange(n, dtype=np.int64))
        total += n
    # flush_walks=0: every push spills immediately as 16-byte records
    assert pool.on_disk_bytes() == total * 16 == stats.walk_bytes_written
    # file content is bit-identical to pack_walks of the stored batches
    batch, _ = pool.peek(3)
    with open(pool.record_path(3), "rb") as f:
        raw = np.frombuffer(f.read(), np.uint32).reshape(-1, 4)
    np.testing.assert_array_equal(raw, pack_walks(batch, STARTS))


def test_pool_flush_threshold_controls_spills():
    """pool_flush_walks is the spill threshold for every backend."""
    stats = IOStats()
    pool = MemoryWalkPool(2, stats, flush_walks=10)
    rng = np.random.default_rng(2)
    pool.push(0, _random_batch(rng, 6, 600), np.arange(6, dtype=np.int64))
    assert stats.walk_bytes_written == 0  # below threshold: buffered only
    pool.push(0, _random_batch(rng, 6, 600), np.arange(6, dtype=np.int64))
    assert stats.walk_bytes_written == 12 * 16  # crossed: whole buffer spilled
    batch, _ = pool.load(0)
    assert len(batch) == 12
    assert stats.walk_bytes_read == 12 * 16  # only spilled walks are re-read


def test_pool_flush_none_never_spills_before_load():
    stats = IOStats()
    pool = MemoryWalkPool(2, stats, flush_walks=None)
    rng = np.random.default_rng(3)
    for _ in range(50):
        pool.push(1, _random_batch(rng, 100, 600), np.zeros(100, np.int64))
    assert stats.walk_bytes_written == 0
    batch, _ = pool.load(1)
    assert len(batch) == 5000 and stats.walk_bytes == 0


def test_make_walk_pool_dispatch(tmp_path):
    stats = IOStats()
    assert make_walk_pool("memory", num_blocks=2, stats=stats).backend == "memory"
    pool = make_walk_pool("disk", num_blocks=2, stats=stats, block_starts=STARTS,
                          directory=str(tmp_path))
    assert pool.backend == "disk"
    assert make_walk_pool(pool, num_blocks=2, stats=stats) is pool
    with pytest.raises(ValueError):
        make_walk_pool("tape", num_blocks=2, stats=stats)
    with pytest.raises(ValueError):
        make_walk_pool("disk", num_blocks=2, stats=stats)  # needs block_starts


# ---------------------------------------------------------------------------
# Engines are deterministic across pool backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Engine", [BiBlockEngine, PlainBucketEngine, SOGWEngine])
def test_engine_bitwise_identical_across_backends(small_blocked, Engine, tmp_path):
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    r_mem = Engine(small_blocked, task).run()
    r_dsk = Engine(small_blocked, task, pool="disk", pool_flush_walks=32,
                   pool_dir=str(tmp_path / Engine.__name__)).run()
    np.testing.assert_array_equal(r_mem.endpoint_counts, r_dsk.endpoint_counts)
    assert r_mem.stats.steps_sampled == r_dsk.stats.steps_sampled
    assert r_mem.stats.block_ios == r_dsk.stats.block_ios
    # the disk run actually moved real bytes through the pool files
    assert r_dsk.stats.walk_bytes_written > 0


@pytest.mark.parametrize("Engine", [BiBlockEngine, PlainBucketEngine, SOGWEngine])
def test_engine_bitwise_identical_across_full_backend_matrix(
    small_blocked, Engine, tmp_path
):
    """Both storage axes at once: a disk walk pool over a disk graph backend
    is bit-identical to the all-in-RAM run (walks AND deterministic I/O)."""
    from repro.io import BLOCK_FILE_NAME, DiskBlockedGraph, write_block_file

    path = str(tmp_path / BLOCK_FILE_NAME)
    write_block_file(small_blocked, path)
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    r_ram = Engine(small_blocked, task).run()
    with DiskBlockedGraph(path) as dg:
        r_all_disk = Engine(
            dg, task, pool="disk", pool_flush_walks=32,
            pool_dir=str(tmp_path / Engine.__name__),
        ).run()
        np.testing.assert_array_equal(r_ram.endpoint_counts, r_all_disk.endpoint_counts)
        assert r_ram.stats.block_ios == r_all_disk.stats.block_ios
        assert r_ram.stats.block_bytes == r_all_disk.stats.block_bytes
        assert r_ram.stats.ondemand_bytes == r_all_disk.stats.ondemand_bytes
        # and both kinds of real bytes actually moved
        assert r_all_disk.stats.walk_bytes_written > 0
        assert dg.data_bytes_read > 0


def test_disk_pool_engine_writes_match_spills(small_blocked, tmp_path):
    task = rwnv_task(walks_per_vertex=2, length=10, seed=7)
    eng = BiBlockEngine(small_blocked, task, pool="disk", pool_flush_walks=16,
                        pool_dir=str(tmp_path))
    res = eng.run()
    assert res.stats.walk_bytes_written == eng.pool.bytes_written > 0


# ---------------------------------------------------------------------------
# BlockStore: prefetch + cache semantics
# ---------------------------------------------------------------------------

def test_prefetched_block_single_charge(small_blocked):
    stats = IOStats()
    store = BlockStore(small_blocked, stats)
    store.prefetch(2)
    blk = store.get(2, sequential=True)
    assert blk.block_id == 2
    # exactly ONE block_load charge: prefetch itself never charges
    assert stats.block_ios == 1
    assert store.prefetch_hits == 1 and store.demand_loads == 0
    store.close()


def test_blockstore_counters_and_lru(small_blocked):
    stats = IOStats()
    store = BlockStore(small_blocked, stats, capacity=2, enable_prefetch=False)
    store.prefetch(0)  # disabled: no-op
    assert store.prefetch_issued == 0
    store.get(0)
    store.get(0)
    assert store.demand_loads == 1 and store.cache_hits == 1
    store.get(1), store.get(2)  # capacity 2: block 0 evicted
    assert store.demand_loads == 3
    store.get(0)  # re-materialised after eviction
    assert store.demand_loads == 4 and store.cache_hits == 1
    # deterministic accounting: every get() charges, cached or not
    assert stats.block_ios == 5
    store.close()


def test_engine_runs_report_prefetch_hits(small_blocked):
    task = rwnv_task(walks_per_vertex=2, length=10, seed=0)
    res = BiBlockEngine(small_blocked, task).run()
    assert res.block_store_counters["prefetch_hits"] > 0
    # prefetch must not change the deterministic I/O accounting
    res_off = BiBlockEngine(small_blocked, task, prefetch=False).run()
    assert res_off.block_store_counters["prefetch_hits"] == 0
    assert res.stats.block_ios == res_off.stats.block_ios
    assert res.stats.ondemand_ios == res_off.stats.ondemand_ios
    np.testing.assert_array_equal(res.endpoint_counts, res_off.endpoint_counts)
