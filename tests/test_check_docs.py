"""The docs contract: scripts/check_docs.py passes on the real tree and
fails on each violation class it claims to catch (dangling link, missing
referenced path, nonexistent repro.* module, missing attribute, unknown
CLI flag)."""

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

import check_docs  # noqa: E402


def test_real_repo_is_clean(capsys):
    assert check_docs.main([str(REPO)]) == 0
    assert "clean" in capsys.readouterr().out


@pytest.fixture
def tree(tmp_path):
    """A minimal passing repo tree the violation tests then break."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro" / "serve").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "serve" / "__init__.py").write_text(
        "class WalkQueryServer: pass\n"
    )
    (tmp_path / "src" / "repro" / "launch").mkdir()
    (tmp_path / "src" / "repro" / "launch" / "serve.py").write_text(
        'ap.add_argument("--max-batch")\n'
    )
    (tmp_path / "README.md").write_text(
        "See [the docs](docs/index.md) and `docs/index.md`.\n"
        "Use `repro.serve.WalkQueryServer` with `--max-batch`.\n"
    )
    (tmp_path / "docs" / "index.md").write_text("All good here.\n")
    assert check_docs.main([str(tmp_path)]) == 0
    return tmp_path


def _errors(tree, capsys):
    rc = check_docs.main([str(tree)])
    return rc, capsys.readouterr().err


def test_dangling_link_fails(tree, capsys):
    (tree / "docs" / "index.md").write_text("[gone](missing.md)\n")
    rc, err = _errors(tree, capsys)
    assert rc == 1 and "dangling link" in err and "missing.md" in err


def test_missing_backtick_path_fails(tree, capsys):
    (tree / "docs" / "index.md").write_text("see `scripts/not_there.py`\n")
    rc, err = _errors(tree, capsys)
    assert rc == 1 and "not_there.py" in err


def test_nonexistent_module_fails(tree, capsys):
    (tree / "docs" / "index.md").write_text("uses `repro.nonexistent.thing`\n")
    rc, err = _errors(tree, capsys)
    assert rc == 1 and "repro.nonexistent.thing" in err


def test_missing_attribute_fails(tree, capsys):
    (tree / "docs" / "index.md").write_text("uses `repro.serve.NoSuchClass`\n")
    rc, err = _errors(tree, capsys)
    assert rc == 1 and "NoSuchClass" in err


def test_unknown_flag_fails(tree, capsys):
    (tree / "docs" / "index.md").write_text("pass `--definitely-not-a-flag`\n")
    rc, err = _errors(tree, capsys)
    assert rc == 1 and "--definitely-not-a-flag" in err


def test_external_tool_flags_are_allowed(tree, capsys):
    (tree / "docs" / "index.md").write_text("run `ruff format --check .`\n")
    rc, _ = _errors(tree, capsys)
    assert rc == 0
