"""Distributed walk engine (shard_map over 8 fake devices) — subprocess
isolated so the main pytest process keeps a single-device jax."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, {src!r})
import numpy as np, jax
from repro.core import erdos_renyi, partition_into_n_blocks, rwnv_task, prnv_task
from repro.core.distributed import DistributedWalkEngine, ring_owner_and_round

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
g = erdos_renyi(800, 6400, seed=3)
bg = partition_into_n_blocks(g, 4)

out = {{}}

# 1) every walk completes
task = rwnv_task(walks_per_vertex=2, length=8, seed=1)
res = DistributedWalkEngine(bg, task, mesh).run()
out["alive"] = int(res["alive"].sum())
out["complete"] = float((res["hop"] == 8).mean())
out["sweeps"] = res["sweeps"]

# 2) ring schedule covers each unordered pair exactly once per sweep
import jax.numpy as jnp
nb = 4
seen = {{}}
for a in range(nb):
    for b in range(nb):
        if a == b: continue
        o, r = ring_owner_and_round(jnp.int32(a), jnp.int32(b), nb)
        key = (min(a, b), max(a, b))
        seen.setdefault(key, set()).add((int(o), int(r)))
out["pair_unique"] = all(len(v) == 1 for v in seen.values())
out["rounds_within_half"] = all(
    list(v)[0][1] <= nb // 2 for v in seen.values()
)

# 3) second-order restart task also drains
taskq = prnv_task(5, g.num_vertices, samples_per_vertex=1, seed=2)
resq = DistributedWalkEngine(bg, taskq, mesh).run()
out["q_alive"] = int(resq["alive"].sum())

print("RESULT " + json.dumps(out))
"""


@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure, re-checked after the async-pipeline PR: "
    "the subprocess dies at mesh construction — jax.sharding.AxisType does "
    "not exist on the pinned jax (0.4.37; the API landed in 0.6), so the "
    "shard_map walk path (incl. PR 3's global walk-id threading) is never "
    "reached; ROADMAP: 'Fix 3 pre-existing failures'",
)
def test_distributed_engine_subprocess():
    code = SCRIPT.format(src=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["alive"] == 0
    assert out["complete"] == 1.0
    assert out["sweeps"] <= 9
    assert out["pair_unique"] and out["rounds_within_half"]
    assert out["q_alive"] == 0


def test_distributed_persists_through_shared_pool(tmp_path):
    """The shard_map driver carries walk state between sweeps through the
    shared :class:`repro.io.ShardedWalkPool` instead of private arrays:
    capacity-limited routing forces a multi-sweep frontier through the
    pool, a disk-backed pool moves real spilled bytes, and — because the
    RNG is counter-based per (walk id, hop) and the drain scatters each
    walk back to its global wid slot — not a single trajectory changes."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import erdos_renyi, partition_into_n_blocks, rwnv_task
    from repro.core.distributed import DistributedWalkEngine

    g = erdos_renyi(300, 2400, seed=3)
    bg = partition_into_n_blocks(g, 1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    task = rwnv_task(p=2.0, q=0.5, walks_per_vertex=1, length=6, seed=5)
    keys = ("prev", "cur", "hop", "alive")

    ref = DistributedWalkEngine(bg, task, mesh).run()
    limited = DistributedWalkEngine(bg, task, mesh, capacity_factor=0.1).run()
    assert limited["sweeps"] > ref["sweeps"]  # the frontier really crossed sweeps
    for k in keys:
        np.testing.assert_array_equal(limited[k], ref[k])

    pool_dir = str(tmp_path / "pool")
    disk = DistributedWalkEngine(
        bg, task, mesh, capacity_factor=0.1,
        pool="disk", pool_flush_walks=0, pool_dir=pool_dir, pool_shards=2,
    ).run()
    for k in keys:
        np.testing.assert_array_equal(disk[k], ref[k])
    s = disk["stats"]
    assert s.walk_bytes_written > 0  # real records moved through the pool
    assert sum(s.shard_spill_bytes.values()) == s.walk_bytes_written
    assert not os.path.isdir(pool_dir), "shared pool spill dir leaked"


def test_distributed_single_device_matches_oracle():
    """In-process pin for the distributed sweep (1x1 mesh, one block): the
    wid-carrying routing + counter-based RNG must reproduce the in-memory
    oracle's walks bitwise — the same identity the multi-rank subprocess
    test asserts when the pinned jax grows shard_map support."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import (
        InMemoryWalker,
        erdos_renyi,
        partition_into_n_blocks,
        rwnv_task,
    )
    from repro.core.distributed import DistributedWalkEngine

    g = erdos_renyi(300, 2400, seed=3)
    bg = partition_into_n_blocks(g, 1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    task = rwnv_task(p=2.0, q=0.5, walks_per_vertex=1, length=6, seed=5)
    out = DistributedWalkEngine(bg, task, mesh).run()
    assert out["alive"].sum() == 0
    oracle = InMemoryWalker(bg, task).run(record_walks=False)
    counts = np.bincount(out["cur"], minlength=g.num_vertices)
    np.testing.assert_array_equal(counts, oracle.endpoint_counts)
