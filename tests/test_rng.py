"""The hand-rolled threefry (kernels/rng.py) is bitwise jax.random.

Every walk draw is keyed ``(base_key, walk_id, hop, round)``; the fused
Pallas kernel re-derives those bits with plain elementwise ops.  These
properties pin the re-derivation to the upstream ``fold_in``/``uniform``
chain exactly — any drift would silently fork the pallas walks from the
jax/oracle walks.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import rng
from repro.testing import given, settings, st


def _f32_bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


@given(
    seed=st.integers(0, 2**31 - 1),
    wid=st.integers(0, 2**31 - 1),
    hop=st.integers(0, 80),
    rnd=st.integers(0, 32),
)
@settings(max_examples=30, deadline=None)
def test_fold_uniform_chain_bitwise(seed, wid, hop, rnd):
    key = jax.random.PRNGKey(seed)
    jk = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), wid), hop)
    h0, h1 = rng.fold_in(*rng.fold_in(*rng.key_halves(key), wid), hop)
    assert int(h0) == int(jk[0]) and int(h1) == int(jk[1])
    # the per-round triple draw (proposal slot, alias coin, accept coin)
    jr = jax.random.fold_in(jk, rnd)
    u3 = jax.random.uniform(jr, (3,))
    h3 = rng.uniform3(*rng.fold_in(h0, h1, rnd))
    np.testing.assert_array_equal(_f32_bits(u3), _f32_bits(jnp.stack(h3)))
    # the scalar termination draw
    ut = jax.random.uniform(jr)
    np.testing.assert_array_equal(
        _f32_bits(ut), _f32_bits(rng.uniform1(*rng.fold_in(h0, h1, rnd)))
    )


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([64, 257]))
@settings(max_examples=10, deadline=None)
def test_fold_in_broadcasts_like_vmap(seed, n):
    key = jax.random.PRNGKey(seed)
    wids = jnp.arange(n, dtype=jnp.int32) * 1021 + 7
    v0, v1 = rng.fold_in(*rng.key_halves(key), wids)
    jv = jax.vmap(lambda w: jax.random.fold_in(key, w))(wids)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(jv[:, 0]))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(jv[:, 1]))


def test_threefry_block_cipher_reference_vector():
    """threefry2x32 against jax's primitive on a fixed counter block."""
    key = jax.random.PRNGKey(123)
    k0, k1 = rng.key_halves(key)
    x = jnp.arange(8, dtype=jnp.uint32)
    ours0, ours1 = rng.threefry2x32(k0, k1, x[:4], x[4:])
    import jax._src.prng as _prng

    theirs = _prng.threefry_2x32(jnp.asarray(key), x)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([ours0, ours1])), np.asarray(theirs)
    )
