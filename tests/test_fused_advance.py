"""The fused Pallas advance (interpret mode) is observationally identical
to the plain jitted JAX advance and to the in-memory oracle.

``advance_impl`` only swaps the lowering of ``UpdateWalk``; every walk,
every endpoint, every step count, and every deterministic I/O charge must
be bit-identical across {full, ondemand} loading x {ram, disk} graph x
{memory, disk} pool, serially and under the async pipeline with sharded
pools.  Any divergence means the kernel's RNG or sampling logic forked
from the engine impl.
"""

import os
import shutil
import tempfile

import numpy as np
import pytest

from repro.core import (
    BiBlockEngine,
    erdos_renyi,
    partition_into_n_blocks,
    rwnv_task,
)
from repro.engines.inmemory import InMemoryWalker
from repro.testing import given, settings, st


def _sig(res):
    return (
        res.endpoint_counts.tobytes(),
        None if res.corpus is None else res.corpus.tobytes(),
        res.stats.steps_sampled,
        res.stats.block_ios,
        res.stats.block_bytes,
        res.stats.ondemand_ios,
        res.stats.ondemand_bytes,
    )


@given(
    seed=st.integers(0, 10_000),
    nv=st.integers(50, 100),
    nblocks=st.integers(2, 4),
    shards=st.sampled_from([1, 4]),
)
@settings(max_examples=2, deadline=None)
def test_fused_advance_matrix_bitwise(seed, nv, nblocks, shards):
    """pallas == jax == oracle across loading x graph x pool, and under the
    async pipeline with pool_shards in {1, 4}."""
    from repro.io import DiskBlockedGraph, write_block_file

    g = erdos_renyi(nv, nv * 5, seed=seed)
    bg = partition_into_n_blocks(g, nblocks)
    task = rwnv_task(p=3.0, q=0.5, walks_per_vertex=1, length=6, seed=seed)
    oracle = InMemoryWalker(bg, task).run(record_walks=True)
    tmp = tempfile.mkdtemp(prefix="grasorw_fused_")
    try:
        path = os.path.join(tmp, "g.grb")
        write_block_file(bg, path)
        for loading in ("full", "ondemand"):
            for backend in ("ram", "disk"):
                for pool in ("memory", "disk"):
                    sigs = {}
                    for impl in ("jax", "pallas"):
                        bgx = bg if backend == "ram" else DiskBlockedGraph(path)
                        res = BiBlockEngine(
                            bgx,
                            task,
                            record_walks=True,
                            async_pipeline=False,
                            loading=loading,
                            pool=pool,
                            pool_dir=os.path.join(
                                tmp, f"p_{loading}_{backend}_{pool}_{impl}"
                            ),
                            advance_impl=impl,
                        ).run()
                        sigs[impl] = _sig(res)
                        # both impls reproduce the oracle walks bitwise
                        np.testing.assert_array_equal(
                            res.endpoint_counts, oracle.endpoint_counts
                        )
                        np.testing.assert_array_equal(res.corpus, oracle.corpus)
                        if backend == "disk":
                            bgx.close()
                    # ... and charge identical deterministic I/O
                    assert sigs["pallas"] == sigs["jax"], (
                        f"diverged at loading={loading} graph={backend} pool={pool}"
                    )
        # the async pipeline with sharded pools rides the same kernel
        r_async = BiBlockEngine(
            bg,
            task,
            record_walks=True,
            async_pipeline=True,
            pool="disk",
            pool_shards=shards,
            pool_dir=os.path.join(tmp, f"p_async_{shards}"),
            advance_impl="pallas",
        ).run()
        np.testing.assert_array_equal(r_async.endpoint_counts, oracle.endpoint_counts)
        np.testing.assert_array_equal(r_async.corpus, oracle.corpus)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_advance_impl_validated():
    bg = partition_into_n_blocks(erdos_renyi(40, 160, seed=0), 2)
    task = rwnv_task(walks_per_vertex=1, length=4, seed=0)
    with pytest.raises(ValueError, match="advance_impl"):
        BiBlockEngine(bg, task, advance_impl="mosaic")


def test_fused_advance_first_order(small_blocked):
    """DeepWalk (order-1, k_max=1) path through the fused kernel."""
    from repro.core import deepwalk_task

    task = deepwalk_task(walks_per_vertex=1, length=8, seed=2)
    r_jax = BiBlockEngine(small_blocked, task, record_walks=True,
                          async_pipeline=False).run()
    r_pal = BiBlockEngine(small_blocked, task, record_walks=True,
                          async_pipeline=False, advance_impl="pallas").run()
    assert _sig(r_jax) == _sig(r_pal)
