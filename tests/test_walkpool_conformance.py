"""WalkPool conformance suite: one contract, four backends.

Every pool backend — Memory, Disk, Async-wrapped, Sharded — must be
observationally identical to the engines.  This suite pins the protocol
contract once, parameterized over the backends, so a new backend (or a
refactor of an old one) is held to the same five invariants:

* **push-order preservation** — ``load`` returns walks in exact push order;
* **prefix + remainder ≡ one serial load** — draining mid-sequence and
  then draining the rest concatenates to what a single slot-start ``load``
  would have returned (for sequenced pools the prefix drain really runs on
  the writer thread, concurrent with the remainder pushes);
* **flush-threshold spill points** — the write buffer spills exactly when
  a block's buffered count crosses ``flush_walks``, charging the same
  walk bytes on every backend (and, sharded, summing the per-shard
  breakdown to the total);
* **idempotent close** — ``close`` twice is safe and removes every spill
  file/directory the pool created;
* **writer-fault latching/propagation** — a failing spill surfaces as a
  RuntimeError from the op stream (synchronously for plain pools, latched
  and re-raised from subsequent ops for sequenced ones), ``close`` never
  hangs, and no spill directory is orphaned.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import IOStats, WalkBatch
from repro.io import AsyncWalkPool, DiskWalkPool, MemoryWalkPool, ShardedWalkPool

NUM_BLOCKS = 6
STARTS = np.array([0, 100, 200, 300, 400, 500, 600])
V = 600

BACKENDS = ("memory", "disk", "async", "sharded")


def _batch(rng, n):
    return WalkBatch(
        rng.integers(0, V, n),
        rng.integers(0, V, n),
        rng.integers(0, V, n),
        rng.integers(0, 100, n).astype(np.int32),
    )


def _settle(pool):
    """Wait out any writer queues so spill charges are observable."""
    if hasattr(pool, "barrier"):
        pool.barrier()


def _spill_dirs(pool):
    """Every on-disk spill directory the pool owns (empty for memory pools)."""
    if isinstance(pool, ShardedWalkPool):
        dirs = [s.base.directory for s in pool.shards if isinstance(s.base, DiskWalkPool)]
        if pool.directory is not None:
            dirs.append(pool.directory)
        return dirs
    if isinstance(pool, AsyncWalkPool):
        pool = pool.base
    return [pool.directory] if isinstance(pool, DiskWalkPool) else []


def _inject_spill_fault(pool):
    def boom(b, batch, wid):
        raise RuntimeError("injected spill failure")

    if isinstance(pool, ShardedWalkPool):
        for shard in pool.shards:
            shard.base._spill = boom
    elif isinstance(pool, AsyncWalkPool):
        pool.base._spill = boom
    else:
        pool._spill = boom


@pytest.fixture(params=BACKENDS)
def backend(request):
    return request.param


@pytest.fixture
def make_pool(backend, tmp_path):
    """Factory building one pool of the parameterized backend; pools get a
    fresh (pool-owned) spill directory each and are closed at teardown —
    which doubles as the close-idempotence check for pools a test already
    closed."""
    pools = []

    def make(stats, flush_walks=1 << 18):
        d = str(tmp_path / f"{backend}_{len(pools)}")
        if backend == "memory":
            pool = MemoryWalkPool(NUM_BLOCKS, stats, flush_walks)
        elif backend == "disk":
            pool = DiskWalkPool(NUM_BLOCKS, stats, STARTS, flush_walks, directory=d)
        elif backend == "async":
            pool = AsyncWalkPool(MemoryWalkPool(NUM_BLOCKS, stats, flush_walks), stats=stats)
        else:
            pool = ShardedWalkPool(
                "disk",
                num_shards=3,
                num_blocks=NUM_BLOCKS,
                stats=stats,
                block_starts=STARTS,
                flush_walks=flush_walks,
                directory=d,
            )
        pools.append(pool)
        return pool

    yield make
    for pool in pools:
        pool.close()


class TestWalkPoolConformance:
    def test_push_order_preserved(self, make_pool):
        pool = make_pool(IOStats(), flush_walks=8)
        rng = np.random.default_rng(0)
        pushed, wids = [], []
        for k in range(5):
            batch = _batch(rng, 7)
            wid = np.arange(7, dtype=np.int64) + 100 * k
            pool.push(3, batch, wid)
            pushed.append(batch)
            wids.append(wid)
        assert pool.counts[3] == 35
        out, wid_out = pool.load(3)
        ref = WalkBatch.concat(pushed)
        np.testing.assert_array_equal(out.src, ref.src)
        np.testing.assert_array_equal(out.prev, ref.prev)
        np.testing.assert_array_equal(out.cur, ref.cur)
        np.testing.assert_array_equal(out.hop, ref.hop)
        np.testing.assert_array_equal(wid_out, np.concatenate(wids))
        assert pool.counts[3] == 0

    def test_drain_prefix_plus_remainder_is_one_serial_load(self, make_pool):
        rng = np.random.default_rng(1)
        batches = [_batch(rng, 7) for _ in range(6)]
        wids = [np.arange(7, dtype=np.int64) + 10 * k for k in range(6)]

        serial = make_pool(IOStats(), flush_walks=10)
        for batch, wid in zip(batches, wids):
            serial.push(2, batch, wid)
        ref, ref_wid = serial.load(2)

        pool = make_pool(IOStats(), flush_walks=10)
        for batch, wid in zip(batches[:3], wids[:3]):
            pool.push(2, batch, wid)
        if hasattr(pool, "drain_async"):
            # the prefix drain runs on the owning writer thread while the
            # remainder pushes are still being enqueued
            fut = pool.drain_async(2)
            for batch, wid in zip(batches[3:], wids[3:]):
                pool.push(2, batch, wid)
            (pre, pre_wid), n_pre, _spilled = fut.result()
            assert n_pre == 21
        else:
            pre, pre_wid = pool.load(2)
            for batch, wid in zip(batches[3:], wids[3:]):
                pool.push(2, batch, wid)
        rem, rem_wid = pool.load(2)
        got = WalkBatch.concat([pre, rem])
        np.testing.assert_array_equal(got.src, ref.src)
        np.testing.assert_array_equal(got.prev, ref.prev)
        np.testing.assert_array_equal(got.cur, ref.cur)
        np.testing.assert_array_equal(got.hop, ref.hop)
        np.testing.assert_array_equal(np.concatenate([pre_wid, rem_wid]), ref_wid)

    def test_flush_threshold_spill_points(self, make_pool, backend):
        stats = IOStats()
        pool = make_pool(stats, flush_walks=10)
        rng = np.random.default_rng(2)
        pool.push(0, _batch(rng, 6), np.arange(6, dtype=np.int64))
        _settle(pool)
        assert stats.walk_bytes_written == 0  # below threshold: buffered only
        pool.push(0, _batch(rng, 6), np.arange(6, dtype=np.int64))
        _settle(pool)
        assert stats.walk_bytes_written == 12 * 16  # crossed: buffer spilled
        pool.push(4, _batch(rng, 9), np.arange(9, dtype=np.int64))
        _settle(pool)
        assert stats.walk_bytes_written == 12 * 16  # other block still buffered
        out, _ = pool.load(0)
        assert len(out) == 12
        assert stats.walk_bytes_read == 12 * 16  # only spilled walks re-read
        out4, _ = pool.load(4)
        assert len(out4) == 9
        assert stats.walk_bytes_read == 12 * 16
        if backend == "sharded":
            assert sum(stats.shard_spill_bytes.values()) == stats.walk_bytes_written

    def test_close_idempotent_and_removes_spill_files(self, make_pool):
        stats = IOStats()
        pool = make_pool(stats, flush_walks=0)  # spill every push
        rng = np.random.default_rng(3)
        for b in (0, 1, 4):
            pool.push(b, _batch(rng, 5), np.arange(5, dtype=np.int64))
        _settle(pool)
        dirs = _spill_dirs(pool)
        pool.close()
        pool.close()
        for d in dirs:
            assert not os.path.isdir(d), f"spill dir {d} survived close()"

    def test_spill_fault_propagates_and_close_does_not_hang(self, make_pool, backend):
        stats = IOStats()
        pool = make_pool(stats, flush_walks=0)  # the fault fires on push 1
        _inject_spill_fault(pool)
        rng = np.random.default_rng(4)
        batch, wid = _batch(rng, 3), np.arange(3, dtype=np.int64)
        if backend in ("memory", "disk"):
            # plain pools spill on the calling thread: immediate propagation
            with pytest.raises(RuntimeError, match="injected"):
                pool.push(0, batch, wid)
        else:
            pool.push(0, batch, wid)  # enqueues; the writer thread faults
            with pytest.raises(RuntimeError):
                pool.barrier()
            # the latched fault re-raises from every subsequent operation
            with pytest.raises(RuntimeError):
                pool.push(0, batch, wid)
            with pytest.raises(RuntimeError):
                pool.load(0)
        dirs = _spill_dirs(pool)
        t = threading.Thread(target=pool.close)
        t.start()
        t.join(timeout=30)
        assert not t.is_alive(), "close() hung after a spill fault"
        for d in dirs:
            assert not os.path.isdir(d), f"spill dir {d} orphaned after fault"
