"""End-to-end driver: GraSorw walk corpus -> train a ~100M-param LM.

This is the production integration the paper enables: Node2vec walk
generation as the corpus engine, then a llama-family model (scaled to ~100M
params so a few hundred CPU steps are feasible) trained on vertex-token
sequences with the resilient trainer (checkpoint/restart, straggler
watchdog).

    PYTHONPATH=src python examples/train_lm_on_walks.py [--steps 300] [--tiny]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BiBlockEngine, erdos_renyi, partition_into_n_blocks, rwnv_task
from repro.data import WalkCorpus
from repro.models import model_init
from repro.models.common import ModelConfig
from repro.optim import OptConfig, adamw_init
from repro.runtime import ResilientTrainer
from repro.train import make_train_step


def lm_100m(vocab: int) -> ModelConfig:
    """~100M llama-family config (8L x 768, GQA 12/4)."""
    return ModelConfig(
        name="walklm-100m",
        d_model=768,
        n_layers=8,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=vocab,
        segments=((("attn+mlp",), 8),),
        mlp_type="swiglu",
        dtype=jnp.float32,
        remat_policy="none",
    )


def lm_tiny(vocab: int) -> ModelConfig:
    """Micro config for smoke runs (2L x 128) — same code path, seconds to train."""
    return ModelConfig(
        name="walklm-tiny",
        d_model=128,
        n_layers=2,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=vocab,
        segments=((("attn+mlp",), 2),),
        mlp_type="swiglu",
        dtype=jnp.float32,
        remat_policy="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--vertices", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/walklm_ckpt")
    ap.add_argument(
        "--tiny",
        action="store_true",
        help="micro model and short walks: the full pipeline end to end in seconds",
    )
    args = ap.parse_args()

    print("phase 1: walk generation (GraSorw bi-block engine)")
    g = erdos_renyi(args.vertices, args.vertices * 8, seed=0)
    bg = partition_into_n_blocks(g, 6)
    walk_len = 10 if args.tiny else 40
    task = rwnv_task(walks_per_vertex=4, length=walk_len, seed=0)
    t0 = time.time()
    res = BiBlockEngine(bg, task, record_walks=True).run()
    print(
        f"  {res.num_walks:,} walks x {task.length} steps in "
        f"{time.time() - t0:.1f}s wall ({res.stats.block_ios} block I/Os)"
    )
    corpus = WalkCorpus.from_walks(res.corpus, g.num_vertices)

    print("phase 2: LM training on the walk corpus")
    cfg = lm_tiny(corpus.vocab_size) if args.tiny else lm_100m(corpus.vocab_size)
    params = model_init(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"  model: {cfg.name}  params={n / 1e6:.1f}M")
    opt_cfg = OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    opt = adamw_init(params)

    trainer = ResilientTrainer(
        train_step=step,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        heartbeat_path=Path(args.ckpt_dir) / "heartbeat",
    )
    resumed = None
    try:
        resumed = trainer.resume(params, opt)
    except Exception:
        pass
    start = 0
    cursor = 0
    if resumed is not None:
        params, opt, start, cursor = resumed
        cursor = cursor or 0
        print(f"  resumed from checkpoint at step {start}")

    losses = []

    def on_metrics(s, m):
        losses.append(m["loss"])
        if s % 20 == 0:
            tail = "  [straggler]" if m["straggler"] else ""
            print(
                f"  step {s:4d}  loss {m['loss']:.4f}  "
                f"lr {m['lr']:.2e}  {m['step_time'] * 1e3:.0f} ms{tail}"
            )

    params, opt, info = trainer.run(
        params,
        opt,
        corpus.batches(args.batch, args.seq, cursor=cursor, seed=1),
        num_steps=args.steps,
        start_step=start,
        on_metrics=on_metrics,
    )
    print(
        f"done: step {info['step']}  final loss {losses[-1]:.4f}  "
        f"(first {losses[0]:.4f}); stragglers flagged: {len(info['stragglers'])}"
    )


if __name__ == "__main__":
    main()
