"""Second-order PageRank point queries through the serving layer.

The original version of this example drove PRNV (Wu et al. 2016) as batch
runs — one engine run per (seed vertex, Node2vec setting).  It now issues
the same queries as *point queries* through `repro.serve.WalkQueryServer`:
queries sharing a (p, q) setting admission-batch into one bi-block sweep,
the hot-set policy pins the traffic's hottest blocks, and each answer's
normalized endpoint multiset is the Monte-Carlo PPR estimate.  The
in-memory oracle comparison is kept: every query's served estimate is
checked against a dedicated oracle PRNV run by total-variation distance.

    PYTHONPATH=src python examples/pagerank_query.py [--vertices 3000]
        [--samples 256] [--length 20] [--hot-blocks 2]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import InMemoryWalker, barabasi_albert, partition_into_n_blocks, prnv_task
from repro.serve import QueryConfig, WalkQueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=3000)
    ap.add_argument("--blocks", type=int, default=5)
    ap.add_argument("--samples", type=int, default=256, help="walks per query")
    ap.add_argument("--length", type=int, default=20)
    ap.add_argument("--hot-blocks", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = barabasi_albert(args.vertices, 6, seed=args.seed)
    bg = partition_into_n_blocks(g, args.blocks)
    queries = [0, 17, min(256, args.vertices - 1)]
    settings = ((1.0, 1.0), (4.0, 0.25), (0.25, 4.0))

    with WalkQueryServer(bg, hot_blocks=args.hot_blocks, seed=args.seed) as server:
        configs = {}
        for p, q in settings:
            cfg = QueryConfig(p=p, q=q, length=args.length, samples=args.samples)
            configs[(p, q)] = cfg
            for v in queries:
                server.submit(v, cfg)
        # one flush serves all three configs, one admission batch each
        answers = {a.qid: a for a in server.flush()}

        qid = 0
        for p, q in settings:
            print(f"\n=== Node2vec(p={p}, q={q}) ===")
            for v in queries:
                a = answers[qid]
                qid += 1
                # oracle reference: a dense PRNV estimate from the same vertex
                task = prnv_task(
                    v,
                    g.num_vertices,
                    p=p,
                    q=q,
                    length=args.length,
                    samples_per_vertex=2,
                    seed=args.seed + 1,
                )
                oracle = InMemoryWalker(bg, task).run(record_walks=False)
                served = a.dense_counts(g.num_vertices) / max(int(a.counts.sum()), 1)
                tv = 0.5 * np.abs(served - oracle.ppr_estimate()).sum()
                print(
                    f"  query {v:5d}: top5={[t for t, _ in a.top(5)]}  "
                    f"latency={a.latency * 1e3:.1f} ms  "
                    f"TV(served, oracle)={tv:.3f}"
                )
        s = server.stats
        lat = server.latency_summary()
        print(
            f"\nserved {lat['answered']} queries in {server.batches_served} "
            f"admission batches: p50={lat['p50'] * 1e3:.1f} ms  "
            f"p95={lat['p95'] * 1e3:.1f} ms"
        )
        print(
            f"block loads={s.block_ios}  pinned hits={s.pinned_block_hits}  "
            f"bytes saved={s.pinned_bytes_saved}"
        )


if __name__ == "__main__":
    main()
