"""Second-order PageRank queries (PRNV, Wu et al. 2016) with GraSorw.

Runs walk-with-restart queries for several seed vertices under different
Node2vec (p, q) settings — the paper's §7.6.1 sensitivity axis — and
compares the bi-block engine against the in-memory oracle.

    PYTHONPATH=src python examples/pagerank_query.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (
    BiBlockEngine,
    InMemoryWalker,
    barabasi_albert,
    partition_into_n_blocks,
    prnv_task,
)


def main():
    g = barabasi_albert(3000, 6, seed=0)
    bg = partition_into_n_blocks(g, 5)
    queries = [0, 17, 256]
    for p, q in ((1.0, 1.0), (4.0, 0.25), (0.25, 4.0)):
        print(f"\n=== Node2vec(p={p}, q={q}) ===")
        for v in queries:
            task = prnv_task(v, g.num_vertices, p=p, q=q, samples_per_vertex=2)
            res = BiBlockEngine(bg, task).run()
            oracle = InMemoryWalker(bg, task).run(record_walks=False)
            ppr = res.ppr_estimate()
            top = np.argsort(-ppr)[:5]
            tv = 0.5 * np.abs(ppr - oracle.ppr_estimate()).sum()
            print(f"  query {v:5d}: top5={[int(t) for t in top]}  "
                  f"sim_wall={res.stats.sim_wall_time*1e3:.1f} ms  "
                  f"TV(engine, oracle)={tv:.3f}")


if __name__ == "__main__":
    main()
