"""Quickstart: second-order walks on a synthetic graph with GraSorw.

Runs the bi-block engine vs the SOGW baseline on a synthetic graph and
prints the paper's headline quantities (block I/Os, vertex I/Os, simulated
wall time), then a PageRank query (PRNV).

    PYTHONPATH=src python examples/quickstart.py [--vertices 5000]
        [--blocks 8] [--length 20]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (
    BiBlockEngine,
    SOGWEngine,
    erdos_renyi,
    partition_into_n_blocks,
    prnv_task,
    rwnv_task,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=5000)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=8)
    ap.add_argument("--walks-per-vertex", type=int, default=2)
    ap.add_argument("--length", type=int, default=20)
    args = ap.parse_args()

    n_edges = args.vertices * args.avg_degree // 2
    print(f"building graph ({args.vertices} vertices, ~{2 * n_edges:,} directed edges)...")
    g = erdos_renyi(args.vertices, n_edges, seed=0)
    bg = partition_into_n_blocks(g, args.blocks)
    print(f"  blocks={bg.num_blocks} edge_cut={bg.edge_cut():.2%}")

    task = rwnv_task(walks_per_vertex=args.walks_per_vertex, length=args.length, seed=0)
    print(
        f"\nRWNV: {task.walks_per_vertex} walks/vertex x len {task.length} "
        f"({task.walks_per_vertex * g.num_vertices * task.length:,} samples)"
    )

    print("\n[GraSorw bi-block engine — disk walk pool + block prefetch]")
    res = BiBlockEngine(bg, task, pool="disk", pool_flush_walks=512).run()
    s = res.stats
    c = res.block_store_counters
    print(f"  block I/Os    : {s.block_ios:6d}  ({s.block_bytes / 1e6:.1f} MB)")
    print(f"  vertex I/Os   : {s.vertex_ios:6d}")
    print(f"  on-demand I/Os: {s.ondemand_ios:6d}")
    print(
        f"  walk spills   : {s.walk_bytes_written / 1e6:.2f} MB written "
        f"(16-byte packed records), {s.walk_bytes_read / 1e6:.2f} MB read"
    )
    print(
        f"  prefetch      : {c['prefetch_hits']} hits / "
        f"{c['prefetch_issued']} issued ({c['cache_hits']} LRU hits)"
    )
    print(
        f"  sim wall time : {s.sim_wall_time:.3f}s "
        f"(I/O {s.sim_io_time:.3f}s + exec {s.exec_time:.3f}s)"
    )
    print(f"  learned eta0  : {res.loader_summary['global_eta0']}")

    print("\n[SOGW baseline (GraphWalker + per-step vertex I/O)]")
    res2 = SOGWEngine(bg, task).run()
    s2 = res2.stats
    print(f"  block I/Os    : {s2.block_ios:6d}")
    print(f"  vertex I/Os   : {s2.vertex_ios:6d}  ({s2.vertex_bytes / 1e6:.1f} MB)")
    print(f"  sim wall time : {s2.sim_wall_time:.3f}s")
    print(
        f"\n  ==> GraSorw speedup: {s2.sim_wall_time / s.sim_wall_time:.1f}x "
        f"(I/O time reduction {s2.sim_io_time / max(s.sim_io_time, 1e-12):.1f}x)"
    )

    print("\nPRNV: second-order PageRank query from vertex 7")
    taskq = prnv_task(7, g.num_vertices, samples_per_vertex=2, seed=1)
    resq = BiBlockEngine(bg, taskq).run()
    ppr = resq.ppr_estimate()
    top = np.argsort(-ppr)[:8]
    print("  top-8 vertices:", [(int(v), round(float(ppr[v]), 4)) for v in top])


if __name__ == "__main__":
    main()
