"""Batched serving demo: prefill + decode loop on a reduced config.

Shows the serve path the dry-run exercises at scale (decode_32k): prefill a
batch of prompts, then decode tokens step by step against the caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-1b]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model_caches, model_init, model_prefill
from repro.train import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    if cfg.skip_decode:
        raise SystemExit(f"{args.arch} has no decode step")
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, P)).astype(np.int32))

    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["prefix"] = jnp.zeros((B, cfg.num_prefix, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)).astype(np.float32)
        )

    max_len = P + args.new_tokens + (cfg.num_prefix if cfg.frontend == "vision" else 0)
    t0 = time.time()
    logits, pcaches = model_prefill(params, batch, cfg)
    print(f"prefill: batch={B} len={P} in {time.time() - t0:.2f}s")

    # pad prefill caches into the fixed decode buffers
    target = model_caches(cfg, B, max_len, enc_len=P)

    def pad(got, tgt):
        if got.shape == tgt.shape:
            return got
        return jnp.pad(got, [(0, t - g) for g, t in zip(got.shape, tgt.shape)])

    caches = jax.tree.map(pad, pcaches, target)

    decode = jax.jit(make_decode_step(cfg), static_argnums=())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = P + (cfg.num_prefix if cfg.frontend == "vision" else 0)
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        tok, _, caches = decode(params, {"token": tok, "cache_len": jnp.int32(pos + i)}, caches)
        tok = tok[:, None]
        out.append(tok)
    dt = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(
        f"decoded {args.new_tokens} tokens per seq in {dt:.2f}s "
        f"({B * args.new_tokens / dt:.1f} tok/s)"
    )
    for b in range(B):
        print(f"  seq {b}: {seqs[b].tolist()}")


if __name__ == "__main__":
    main()
