#!/usr/bin/env python3
"""Docs contract checker (stdlib-only; runs in the ruff-only lint job).

Validates, over ``README.md`` and every ``docs/*.md`` page:

1. **Links** — every relative markdown link ``[text](target)`` resolves to
   an existing file (fragments stripped), and every backtick-quoted
   repo path (````docs/serving.md````, ````benchmarks/bench_walks.py````,
   ...) exists on disk.
2. **Module paths** — every ``repro.*`` dotted path names a real module
   under ``src/repro`` (resolved against the file tree, no imports); a
   trailing attribute (``repro.serve.WalkQueryServer``) must appear
   textually in the resolved module/package sources.
3. **CLI flags** — every ``--flag`` mentioned must be defined by an
   ``add_argument`` call somewhere in ``src/``, ``benchmarks/``,
   ``examples/``, or ``scripts/``.

Exit code 0 when clean; 1 with one line per violation otherwise.  Pass a
repo root to check a different tree (used by the tests).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

#: flags that belong to external tools mentioned in prose, not to us
EXTERNAL_FLAGS = {"--check", "--upgrade", "--help"}

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICK_PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:md|py|yml|toml))`")
MODULE_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9]*(?:-[a-z0-9]+)*\b")
ADD_ARG_RE = re.compile(r"add_argument\(\s*[\"'](--[a-z0-9-]+)[\"']")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def defined_flags(root: Path) -> set:
    flags = set(EXTERNAL_FLAGS)
    for sub in ("src", "benchmarks", "examples", "scripts"):
        base = root / sub
        if not base.is_dir():
            continue
        for py in base.rglob("*.py"):
            flags.update(ADD_ARG_RE.findall(py.read_text(encoding="utf-8")))
    return flags


def resolve_module(root: Path, dotted: str):
    """Longest prefix of ``dotted`` that is a real module under src/;
    returns (module_paths, remaining_attrs) or (None, None)."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        rel = Path(*parts[:cut])
        mod = root / "src" / rel.with_suffix(".py")
        pkg = root / "src" / rel / "__init__.py"
        if mod.is_file():
            return [mod], parts[cut:]
        if pkg.is_file():
            # attributes of a package may live in (and re-export from)
            # any of its modules — search the whole package dir
            return sorted((root / "src" / rel).glob("*.py")), parts[cut:]
    return None, None


def check_file(root: Path, doc: Path, flags: set) -> list[str]:
    text = doc.read_text(encoding="utf-8")
    rel = doc.relative_to(root)
    errors = []

    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = (doc.parent / target.split("#", 1)[0]).resolve()
        if not path.exists():
            errors.append(f"{rel}: dangling link -> {target}")

    for target in TICK_PATH_RE.findall(text):
        if not ((root / target).exists() or (doc.parent / target).exists()):
            errors.append(f"{rel}: referenced path does not exist -> {target}")

    for dotted in sorted(set(MODULE_RE.findall(text))):
        sources, attrs = resolve_module(root, dotted)
        if sources is None:
            errors.append(f"{rel}: module path does not exist -> {dotted}")
            continue
        if attrs:  # first attribute must appear in the resolved sources
            name = attrs[0]
            if not any(
                re.search(rf"\b{re.escape(name)}\b", p.read_text(encoding="utf-8"))
                for p in sources
            ):
                errors.append(
                    f"{rel}: {dotted} -> no '{name}' in {'/'.join(dotted.split('.')[: -len(attrs)])}"
                )

    for flag in sorted(set(FLAG_RE.findall(text))):
        if flag not in flags:
            errors.append(f"{rel}: flag not defined by any add_argument -> {flag}")

    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = Path(argv[0]).resolve() if argv else Path(__file__).resolve().parents[1]
    flags = defined_flags(root)
    errors = []
    for doc in doc_files(root):
        errors.extend(check_file(root, doc, flags))
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        n = len(doc_files(root))
        print(f"check_docs: {n} files clean ({len(flags)} known flags)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
