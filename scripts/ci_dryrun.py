"""Replay .github/workflows/ci.yml locally — an `act`-style dry run.

Walks every job in the workflow and executes each `run:` step with bash in
the repo root, merging workflow/job/step `env:` blocks.  Steps that cannot
run outside the GitHub runner image are *simulated* and reported as SKIP:

* `uses:` actions (checkout / setup-python / pip cache) — except
  `upload-artifact`, whose declared paths are verified to exist, so the
  bench-smoke contract is still checked end to end;
* `pip install` steps (the container must not grow dependencies);
* steps invoking tools that are not installed (e.g. `ruff`);
* matrix legs that do not match the local interpreter — the matrix is
  collapsed to the one leg this Python can honestly execute.

Exit status is non-zero iff any executed step fails, so

    python scripts/ci_dryrun.py [--timeout 1800]

is the local equivalent of a green/red CI run.
"""

from __future__ import annotations

import argparse
import importlib.util
import os
import shutil
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
WORKFLOW = REPO / ".github" / "workflows" / "ci.yml"

GREEN, RED, YELLOW, RESET = "\x1b[32m", "\x1b[31m", "\x1b[33m", "\x1b[0m"


def load_workflow() -> dict:
    try:
        import yaml
    except ImportError:
        print("PyYAML is required for the dry run (python -m pip show pyyaml)")
        raise SystemExit(2)
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


def have(tool: str) -> bool:
    return shutil.which(tool) is not None


def have_module(name: str) -> bool:
    return importlib.util.find_spec(name) is not None


def step_skip_reason(step: dict) -> str | None:
    """Why this step cannot be executed locally (None = runnable)."""
    uses = step.get("uses")
    if uses is not None and "upload-artifact" not in uses:
        return f"simulated action {uses}"
    run = step.get("run", "")
    if "pip install" in run:
        return "pip install (container deps are frozen)"
    if run.lstrip().startswith("ruff") and not have("ruff"):
        return "ruff not installed here"
    cond = step.get("if", "")
    if "matrix.hypothesis == 'yes'" in cond:
        return "hypothesis leg (collapsed matrix)"
    if "matrix.hypothesis == 'no'" in cond and have_module("hypothesis"):
        return "no-hypothesis leg, but hypothesis is installed"
    return None


def run_step(step: dict, env: dict, timeout: int) -> tuple[str, str]:
    """Execute one step; returns (status, detail)."""
    uses = step.get("uses")
    if uses is not None and "upload-artifact" in uses:
        paths = str(step.get("with", {}).get("path", "")).split()
        missing = [p for p in paths if not (REPO / p).exists()]
        if missing:
            return "FAIL", f"artifact paths missing: {missing}"
        return "PASS", f"artifact paths exist: {paths}"
    proc = subprocess.run(
        ["bash", "-eo", "pipefail", "-c", step["run"]],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        tail = (proc.stdout + proc.stderr)[-2000:]
        return "FAIL", f"exit {proc.returncode}\n{tail}"
    return "PASS", ""


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--timeout", type=int, default=1800, help="seconds per step")
    ap.add_argument("jobs", nargs="*", help="job ids to replay (default: all)")
    args = ap.parse_args(argv)

    wf = load_workflow()
    failures = 0
    for job_id, job in wf["jobs"].items():
        if args.jobs and job_id not in args.jobs:
            continue
        print(f"\n== job: {job_id} ({job.get('name', job_id)}) ==")
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "")
        for scope in (wf.get("env", {}), job.get("env", {})):
            env.update({k: str(v) for k, v in scope.items()})
        for step in job.get("steps", []):
            label = step.get("name") or step.get("uses") or step.get("run", "")[:60]
            reason = step_skip_reason(step)
            if reason is not None:
                print(f"  {YELLOW}SKIP{RESET} {label}  [{reason}]")
                continue
            step_env = dict(env)
            step_env.update({k: str(v) for k, v in step.get("env", {}).items()})
            try:
                status, detail = run_step(step, step_env, args.timeout)
            except subprocess.TimeoutExpired:
                status, detail = "FAIL", f"timed out after {args.timeout}s"
            color = GREEN if status == "PASS" else RED
            print(f"  {color}{status}{RESET} {label}" + (f"\n{detail}" if detail else ""))
            if status == "FAIL":
                failures += 1
    print(f"\n{'DRY RUN GREEN' if failures == 0 else f'{failures} step(s) FAILED'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
